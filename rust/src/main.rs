//! `topk-eigen` — command-line front end for the Top-K sparse eigensolver.
//!
//! Subcommands:
//! * `solve <input>` — solve a MatrixMarket file or a Table II catalog ID
//!   (e.g. `WB-GO@64` = web-Google twin at 1/64 scale).
//! * `serve <input>` — matrix-resident serving session: register the
//!   matrix once, run a mixed-K job trace through `EigenService` worker
//!   replicas against the shared prepared engine, print service and
//!   registry telemetry.
//! * `query <input>` — streaming Top-K SpMV queries on the resident
//!   matrix (dense vector x matrix, global top-k rows via per-CU heaps).
//! * `ppr <input>` — Personalized PageRank power iteration on the
//!   resident matrix's reduced-precision stored values.
//! * `catalog` — print the Table II dataset catalog.
//! * `generate <id> <out.mtx>` — materialize a synthetic twin to a file.
//! * `export-ooc <input> <dir>` — serialize a matrix into an out-of-core
//!   packet directory (then `solve --ooc <dir>` streams it from disk).
//! * `generate-ooc <dir>` — stream an R-MAT graph directly into a packet
//!   directory without ever materializing it (graphs larger than RAM).
//! * `model <input>` — print the FPGA timing/resource/power model estimate.
//! * `artifacts` — verify the AOT artifact set (`make artifacts`).
#![allow(clippy::needless_range_loop, clippy::excessive_precision)]

use topk_eigen::coordinator::service::{EigenService, QueuePolicy, ServiceConfig};
use topk_eigen::coordinator::{verify, Engine, RegistryConfig, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::fpga::{FpgaTimingModel, PowerModel, SlrBudget};
use topk_eigen::graphs;
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::sparse::{
    partition_rows_balanced, read_matrix_market, CooDelta, CooMatrix, PartitionPolicy, PprOptions, TopKHeap,
};
use topk_eigen::util::cli::Command;
use topk_eigen::util::timer::fmt_duration;

fn main() {
    topk_eigen::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("solve") => cmd_solve(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("query") => cmd_query(&args[1..]),
        Some("ppr") => cmd_ppr(&args[1..]),
        Some("catalog") => cmd_catalog(),
        Some("generate") => cmd_generate(&args[1..]),
        Some("export-ooc") => cmd_export_ooc(&args[1..]),
        Some("generate-ooc") => cmd_generate_ooc(&args[1..]),
        Some("model") => cmd_model(&args[1..]),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            eprintln!(
                "topk-eigen — Top-K sparse graph eigensolver (Lanczos + systolic Jacobi)\n\n\
                 USAGE:\n  topk-eigen <solve|serve|query|ppr|catalog|generate|export-ooc|generate-ooc|model|artifacts> [...]\n\n\
                 Run `topk-eigen solve --help` etc. for details."
            );
            2
        }
    };
    std::process::exit(code);
}

/// Resolve `input`: a path to a `.mtx` file, or `ID[@scale]` from the
/// catalog (e.g. `WB-GO@64`).
fn load_input(input: &str) -> Result<CooMatrix, String> {
    if std::path::Path::new(input).exists() {
        return read_matrix_market(input).map_err(|e| e.to_string());
    }
    let (id, scale) = match input.split_once('@') {
        Some((id, s)) => (id, s.parse::<usize>().map_err(|e| format!("bad scale: {e}"))?),
        None => (input, 64),
    };
    let entry = graphs::catalog()
        .into_iter()
        .find(|e| e.id.eq_ignore_ascii_case(id))
        .ok_or_else(|| format!("'{input}' is neither a file nor a catalog ID (try `topk-eigen catalog`)"))?;
    log::info!("generating {} twin at 1/{scale} scale", entry.name);
    Ok(entry.generate(scale))
}

fn parse_reorth(s: &str) -> Result<ReorthPolicy, String> {
    match s {
        "none" => Ok(ReorthPolicy::None),
        "every" => Ok(ReorthPolicy::Every),
        other => other
            .strip_prefix("every-")
            .and_then(|n| n.parse().ok())
            .map(ReorthPolicy::EveryN)
            .ok_or_else(|| format!("bad reorth '{other}' (none|every|every-N)")),
    }
}

fn parse_precision(s: &str) -> Result<Precision, String> {
    match s {
        "f32" => Ok(Precision::Float32),
        "q1.31" => Ok(Precision::FixedQ1_31),
        "q2.30" => Ok(Precision::FixedQ2_30),
        "q1.15" => Ok(Precision::FixedQ1_15),
        other => Err(format!("bad precision '{other}' (f32|q1.31|q2.30|q1.15)")),
    }
}

fn parse_adaptive(s: &str) -> Result<Option<f64>, String> {
    let tol: f64 = s.parse().map_err(|e| format!("bad adaptive tolerance '{s}': {e}"))?;
    if tol < 0.0 {
        return Err(format!("adaptive tolerance must be >= 0, got {tol}"));
    }
    Ok(if tol == 0.0 { None } else { Some(tol) })
}

fn parse_partition(s: &str) -> Result<PartitionPolicy, String> {
    match s {
        "equal-rows" => Ok(PartitionPolicy::EqualRows),
        "balanced-nnz" => Ok(PartitionPolicy::BalancedNnz),
        other => Err(format!("bad partition '{other}' (equal-rows|balanced-nnz)")),
    }
}

fn cmd_solve(args: &[String]) -> i32 {
    let cmd = Command::new("topk-eigen solve", "solve a Top-K sparse eigenproblem")
        .positional_opt("input", "MatrixMarket file or catalog ID[@scale] (omit with --ooc)")
        .opt("ooc", "stream the matrix out-of-core from a packet directory (see `export-ooc`/`generate-ooc`)", None)
        .opt("k", "number of eigenpairs", Some("8"))
        .opt("reorth", "reorthogonalization: none|every|every-N", Some("every-2"))
        .opt("precision", "f32|q1.31|q2.30|q1.15", Some("f32"))
        .opt("cus", "SpMV compute units (matrix row shards)", Some("5"))
        .opt("threads", "CU pool worker threads (0 = one per CU)", Some("0"))
        .opt("partition", "row partition: equal-rows|balanced-nnz", Some("balanced-nnz"))
        .opt("engine", "spmv engine: native|pjrt", Some("native"))
        .opt("adaptive", "adaptive Lanczos stop: Ritz tolerance (0 = paper's fixed K iterations)", Some("0"))
        .opt("block", "block-Lanczos width b: columns advanced per matrix stream (1 = single-vector)", Some("1"))
        .flag("no-fuse", "disable the fused Lanczos datapath (serial per-pass vector phase)")
        .flag("skip-symmetry-check", "trust the input to be symmetric (skips the O(nnz) prepare-time check)")
        .flag("verify", "print Fig-11 accuracy metrics")
        .flag("quiet", "suppress per-pair output");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let opts = SolveOptions {
            k: m.parse_at_least::<usize>("k", 1).map_err(|e| e.to_string())?,
            reorth: parse_reorth(m.str("reorth").unwrap())?,
            precision: parse_precision(m.str("precision").unwrap())?,
            cus: m.parse_at_least::<usize>("cus", 1).map_err(|e| e.to_string())?,
            threads: m.parse::<usize>("threads").map_err(|e| e.to_string())?,
            partition: parse_partition(m.str("partition").unwrap())?,
            engine: match m.str("engine").unwrap() {
                "pjrt" => Engine::Pjrt,
                _ => Engine::Native,
            },
            fuse: !m.flag("no-fuse"),
            skip_symmetry_check: m.flag("skip-symmetry-check"),
            adaptive_tol: parse_adaptive(m.str("adaptive").unwrap())?,
            block_size: m.parse_at_least::<usize>("block", 1).map_err(|e| e.to_string())?,
            ..Default::default()
        };
        let mut solver = Solver::new(opts.clone());
        // Bind the engine: resident (load + normalize + shard) or
        // out-of-core (manifest + double-buffered packet streaming; shard
        // geometry comes from the directory, not --cus/--partition).
        let (prep, matrix) = match m.get("ooc") {
            Some(dir) => {
                if m.flag("verify") {
                    return Err("--verify recomputes residuals against the resident matrix; run without --ooc".into());
                }
                (solver.prepare_ooc(dir).map_err(|e| format!("{e:#}"))?, None)
            }
            None => {
                let input = m
                    .get("input")
                    .ok_or_else(|| "missing <input> (pass a matrix, or --ooc <dir>)".to_string())?;
                let matrix = load_input(input)?;
                if matrix.nrows != matrix.ncols {
                    return Err("matrix must be square".into());
                }
                let prep = solver.prepare(&matrix).map_err(|e| e.to_string())?;
                (prep, Some(matrix))
            }
        };
        println!(
            "solving: n={} nnz={} k={} reorth={} precision={} cus={} threads={} partition={:?} engine={} fuse={} block={}",
            prep.n(),
            prep.nnz(),
            opts.k,
            opts.reorth.name(),
            opts.precision.name(),
            opts.cus,
            opts.effective_threads(),
            opts.partition,
            prep.engine(),
            opts.fuse,
            opts.block_size
        );
        let sol = solver.solve_prepared(&prep).map_err(|e| e.to_string())?;
        if !m.flag("quiet") {
            for (i, (lambda, _)) in sol.pairs().enumerate() {
                println!("  lambda[{i}] = {lambda:+.8}");
            }
        }
        let mt = &sol.metrics;
        println!(
            "phases: prepare={} lanczos={} jacobi={} lift={} (engine={}, spmv={}, sweeps={})",
            fmt_duration(mt.prepare_s),
            fmt_duration(mt.lanczos_s),
            fmt_duration(mt.jacobi_s),
            fmt_duration(mt.lift_s),
            mt.engine_used,
            mt.spmv_count,
            mt.systolic.sweeps,
        );
        println!(
            "lanczos datapath: block={} matrix-passes={} fused-sweeps={} vector-passes={}",
            mt.block_size, mt.matrix_passes, mt.fused_sweeps, mt.vector_passes,
        );
        println!(
            "datapath: precision={} entries/line={} value-bytes={} basis-bytes={} packets={} hbm-bytes={}",
            mt.precision,
            mt.packet_capacity,
            mt.value_bytes,
            mt.basis_bytes,
            mt.packets_streamed,
            mt.bytes_streamed,
        );
        if let Some(b) = mt.breakdown_at {
            println!("note: Lanczos breakdown at iteration {b} (exact invariant subspace)");
        }
        if prep.engine() == "native-ooc" {
            println!(
                "ooc: io-bytes={} prefetch-stalls={} effective={:.1} MB/s",
                mt.io_bytes_read,
                mt.prefetch_stalls,
                mt.io_bytes_read as f64 / mt.lanczos_s.max(1e-9) / 1e6,
            );
        }
        if m.flag("verify") {
            let matrix = matrix.as_ref().expect("--verify is rejected with --ooc");
            let r = verify::verify(matrix, &sol);
            println!(
                "accuracy: mean-angle={:.3}deg max-cross-dot={:.2e} mean-residual={:.2e} max-residual={:.2e}",
                r.mean_angle_deg, r.max_cross_dot, r.mean_residual, r.max_residual
            );
        }
        Ok(0)
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_serve(args: &[String]) -> i32 {
    let cmd = Command::new("topk-eigen serve", "matrix-resident serving session over one registered matrix")
        .positional_opt("input", "MatrixMarket file or catalog ID[@scale] (omit with --ooc)")
        .opt("ooc", "serve a packet directory out-of-core (updates disabled; shard geometry from the manifest)", None)
        .opt("ooc-budget-mb", "max chunk-buffer bytes an OOC engine may pin, in MiB (0 = unlimited)", Some("0"))
        .opt("replicas", "solver worker replicas", Some("2"))
        .opt("jobs", "jobs in the trace (cycling through --ks)", Some("32"))
        .opt("ks", "comma-separated K values of the trace", Some("4,8,16,32"))
        .opt("policy", "queue policy: fifo|kbatched", Some("kbatched"))
        .opt("reorth", "reorthogonalization: none|every|every-N", Some("every-2"))
        .opt("precision", "f32|q1.31|q2.30|q1.15", Some("f32"))
        .opt("cus", "SpMV compute units (matrix row shards)", Some("5"))
        .opt("threads", "CU pool worker threads (0 = one per CU)", Some("0"))
        .opt("budget-mb", "registry engine byte budget in MiB (0 = unlimited)", Some("0"))
        .opt("updates", "delta updates interleaved with the trace (evolving-graph replay)", Some("0"))
        .opt("update-dirty", "fraction of entries each delta perturbs (e.g. 0.01 = 1%)", Some("0.01"))
        .opt("queries", "Top-K SpMV queries interleaved per phase (mixed eigen+query load)", Some("0"))
        .opt("query-k", "top rows per interleaved query", Some("8"))
        .opt("pprs", "Personalized PageRank jobs interleaved per phase", Some("0"))
        .opt("batch-cap", "max Top-K queries coalesced into one batched sweep (1 disables)", Some("8"))
        .opt("adaptive", "adaptive Lanczos stop: Ritz tolerance (0 = fixed K iterations)", Some("0"))
        .opt("block", "block-Lanczos width b for the eigensolve jobs (1 = single-vector)", Some("1"))
        .flag("warm-start", "seed repeated (handle, k) queries from the previous Ritz front (panel at --block > 1)")
        .flag("skip-symmetry-check", "trust inputs to be symmetric (skips the O(nnz) registration check)")
        .flag("quiet", "suppress per-job output");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let replicas = m.parse_at_least::<usize>("replicas", 1).map_err(|e| e.to_string())?;
        let jobs = m.parse_at_least::<usize>("jobs", 1).map_err(|e| e.to_string())?;
        let ks = m.parse_list::<usize>("ks").map_err(|e| e.to_string())?;
        if ks.is_empty() {
            return Err("--ks must name at least one K".into());
        }
        let policy = QueuePolicy::parse(m.str("policy").unwrap())
            .ok_or_else(|| format!("bad policy '{}' (fifo|kbatched)", m.str("policy").unwrap()))?;
        let opts = SolveOptions {
            reorth: parse_reorth(m.str("reorth").unwrap())?,
            precision: parse_precision(m.str("precision").unwrap())?,
            cus: m.parse_at_least::<usize>("cus", 1).map_err(|e| e.to_string())?,
            threads: m.parse::<usize>("threads").map_err(|e| e.to_string())?,
            adaptive_tol: parse_adaptive(m.str("adaptive").unwrap())?,
            block_size: m.parse_at_least::<usize>("block", 1).map_err(|e| e.to_string())?,
            ..Default::default()
        };
        let budget_mb = m.parse::<usize>("budget-mb").map_err(|e| e.to_string())?;
        let ooc_budget_mb = m.parse::<usize>("ooc-budget-mb").map_err(|e| e.to_string())?;
        let updates = m.parse::<usize>("updates").map_err(|e| e.to_string())?;
        let queries = m.parse::<usize>("queries").map_err(|e| e.to_string())?;
        let query_k = m.parse_at_least::<usize>("query-k", 1).map_err(|e| e.to_string())?;
        let pprs = m.parse::<usize>("pprs").map_err(|e| e.to_string())?;
        let update_dirty = m.parse::<f64>("update-dirty").map_err(|e| e.to_string())?;
        if !(0.0..=1.0).contains(&update_dirty) {
            return Err(format!("--update-dirty must be in [0, 1], got {update_dirty}"));
        }
        let batch_cap = m.parse_at_least::<usize>("batch-cap", 1).map_err(|e| e.to_string())?;
        let svc = EigenService::with_config(ServiceConfig {
            replicas,
            policy,
            registry: RegistryConfig {
                budget_bytes: budget_mb * (1 << 20),
                ooc_buffer_budget_bytes: ooc_budget_mb * (1 << 20),
                warm_start: m.flag("warm-start"),
                skip_symmetry_check: m.flag("skip-symmetry-check"),
                ..Default::default()
            },
            paused: false,
            batch_cap,
        });
        let t0 = std::time::Instant::now();
        // Residency source: a loaded matrix (with a canonical mirror kept
        // in sync for the evolving-graph replay), or an out-of-core packet
        // directory (immutable: updates are rejected at registration time
        // here rather than mid-trace).
        let (handle, n, nnz, mut mirror) = match m.get("ooc") {
            Some(dir) => {
                if updates > 0 {
                    return Err(
                        "--updates needs a resident matrix: packet files store pre-quantized bits \
                         and cannot be spliced in place"
                            .into(),
                    );
                }
                let handle = svc.registry().register_ooc(dir).map_err(|e| format!("{e:#}"))?;
                let (n, nnz) = svc.registry().dims(handle).ok_or("registered handle vanished")?;
                (handle, n, nnz, None)
            }
            None => {
                let input = m
                    .get("input")
                    .ok_or_else(|| "missing <input> (pass a matrix, or --ooc <dir>)".to_string())?;
                let matrix = load_input(input)?;
                // Mirror of the registered matrix's canonical content, kept
                // in sync with every applied delta so each generated delta
                // perturbs the *current* values.
                let mut mirror = matrix.clone();
                mirror.canonicalize();
                let (n, nnz) = (matrix.nrows, matrix.nnz());
                let handle = svc.register(matrix).map_err(|e| e.to_string())?;
                (handle, n, nnz, Some(mirror))
            }
        };
        println!(
            "serving: n={n} nnz={nnz} replicas={replicas} policy={} jobs={jobs} ks={ks:?} precision={} block={} warm-start={}{}",
            policy.name(),
            opts.precision.name(),
            opts.block_size,
            m.flag("warm-start"),
            if m.get("ooc").is_some() { " (out-of-core)" } else { "" },
        );
        let mut ok = 0usize;
        let mut query_ok = 0usize;
        let mut ppr_ok = 0usize;
        let quiet = m.flag("quiet");
        let phases = updates + 1;
        for phase in 0..phases {
            let (lo, hi) = (jobs * phase / phases, jobs * (phase + 1) / phases);
            let tickets: Vec<_> = (lo..hi)
                .map(|i| svc.submit_handle(handle, SolveOptions { k: ks[i % ks.len()], ..opts.clone() }))
                .collect();
            // Mixed offered load: the queries and PPR walks enter the same
            // queue as the eigensolves of this phase and drain on the same
            // replicas, generation-fenced against the phase updates.
            let query_tickets: Vec<_> = (0..queries)
                .map(|q| {
                    let x = query_vector(n, (phase * queries + q) as u64 + 1);
                    svc.submit_query(handle, x, query_k, opts.clone())
                })
                .collect();
            let ppr_tickets: Vec<_> = (0..pprs)
                .map(|p| {
                    let popts = PprOptions { source: (phase * pprs + p * 7) % n, ..Default::default() };
                    svc.submit_ppr(handle, popts, opts.clone())
                })
                .collect();
            for (id, t) in tickets {
                let r = t.wait();
                match r.outcome {
                    Ok(sol) => {
                        ok += 1;
                        if !quiet {
                            println!(
                                "  job {id}: k={} gen={} lambda0={:+.6} queued={} solve={} spmv={} passes={}{}",
                                sol.k(),
                                sol.metrics.generation,
                                sol.eigenvalues[0],
                                fmt_duration(r.queued_s),
                                fmt_duration(r.solve_s),
                                sol.metrics.spmv_count,
                                sol.metrics.matrix_passes,
                                if sol.metrics.warm_started { " (warm)" } else { "" },
                            );
                        }
                    }
                    Err(e) => println!("  job {id} FAILED: {e}"),
                }
            }
            for (id, t) in query_tickets {
                let r = t.wait();
                match r.outcome {
                    Ok(ans) => {
                        query_ok += 1;
                        if !quiet {
                            let top = ans.entries.first();
                            println!(
                                "  query {id}: gen={} top1={} queued={} took={}",
                                ans.generation,
                                top.map_or("-".to_string(), |e| format!("(row {}, {:+.3e})", e.index, e.score)),
                                fmt_duration(r.queued_s),
                                fmt_duration(r.query_s),
                            );
                        }
                    }
                    Err(e) => println!("  query {id} FAILED: {e}"),
                }
            }
            for (id, t) in ppr_tickets {
                let r = t.wait();
                match r.outcome {
                    Ok(ans) => {
                        ppr_ok += 1;
                        if !quiet {
                            println!(
                                "  ppr {id}: gen={} iters={} delta={:.2e}{} queued={} took={}",
                                ans.generation,
                                ans.ppr.iterations,
                                ans.ppr.l1_delta,
                                if ans.ppr.converged { "" } else { " (no convergence)" },
                                fmt_duration(r.queued_s),
                                fmt_duration(r.query_s),
                            );
                        }
                    }
                    Err(e) => println!("  ppr {id} FAILED: {e}"),
                }
            }
            if phase + 1 < phases {
                let mirror = mirror.as_mut().expect("updates require a resident matrix");
                let delta = perturbation_delta(mirror, update_dirty, phase);
                let mut local = delta.clone();
                local.canonicalize();
                mirror.apply_delta(&local);
                let (uid, ut) = svc.submit_update(handle, delta);
                let r = ut.wait();
                match r.outcome {
                    Ok(rep) => println!(
                        "  update {uid}: gen={} dirty-rows={} changed={} rel-delta={:.2e} warm-{} took={}",
                        rep.generation,
                        rep.dirty_rows,
                        rep.changed,
                        rep.rel_delta,
                        if rep.warm_kept { "kept" } else { "dropped" },
                        fmt_duration(r.update_s),
                    ),
                    Err(e) => println!("  update {uid} FAILED: {e}"),
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = svc.stats();
        let rstats = svc.registry().stats();
        let query_total = queries * phases;
        let ppr_total = pprs * phases;
        println!(
            "served {ok}/{jobs} jobs in {} -> {:.1} jobs/s ({} reconfigs under {})",
            fmt_duration(wall),
            jobs as f64 / wall,
            stats.reconfigs,
            policy.name(),
        );
        if query_total + ppr_total > 0 {
            println!(
                "mixed load: queries={query_ok}/{query_total} pprs={ppr_ok}/{ppr_total} \
                 colsum-builds={} colsum-hits={}",
                rstats.colsum_builds, rstats.colsum_hits,
            );
            println!(
                "query path: batches={} batched-queries={} shards-skipped={} \
                 rowbound-builds={} rowbound-hits={} ppr-warm-hits={}",
                stats.query_batches,
                stats.batched_queries,
                stats.shards_skipped,
                rstats.rowbound_builds,
                rstats.rowbound_hits,
                rstats.ppr_warm_hits,
            );
        }
        println!(
            "registry: matrices={} engines={} prepares={} engine-hits={} dedup-hits={} evictions={} \
             resident={:.1}MiB warm-hits={}",
            rstats.matrices,
            rstats.engines,
            rstats.prepares,
            rstats.engine_hits,
            rstats.dedup_hits,
            rstats.evictions,
            rstats.resident_bytes as f64 / (1 << 20) as f64,
            rstats.warm_hits,
        );
        if updates > 0 {
            println!(
                "updates: applied={} incremental-rebuilds={} full-rebuilds={} shards-rebuilt={} \
                 shards-reused={} warm-kept={} warm-dropped={}",
                rstats.updates,
                rstats.incremental_rebuilds,
                rstats.full_rebuilds,
                rstats.shards_rebuilt,
                rstats.shards_reused,
                rstats.warm_kept,
                rstats.warm_dropped,
            );
        }
        println!(
            "queue: total-wait={} max-wait={} total-solve={}",
            fmt_duration(stats.total_queued_s),
            fmt_duration(stats.max_queued_s),
            fmt_duration(stats.total_solve_s),
        );
        svc.shutdown();
        let failed = (jobs - ok) + (query_total - query_ok) + (ppr_total - ppr_ok);
        if failed == 0 {
            Ok(0)
        } else {
            Err(format!("{failed} of {} jobs failed", jobs + query_total + ppr_total))
        }
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

/// A symmetric value-perturbation delta touching roughly `frac` of the
/// upper-triangle entries of the (canonical) mirror, phase-shifted by
/// `round` so successive updates touch different entries.
fn perturbation_delta(mirror: &CooMatrix, frac: f64, round: usize) -> CooDelta {
    let stride = ((1.0 / frac.max(1e-9)) as usize).max(1);
    let mut d = CooDelta::new(mirror.nrows, mirror.ncols);
    let mut picked = 0usize;
    for i in 0..mirror.nnz() {
        let (r, c) = (mirror.rows[i] as usize, mirror.cols[i] as usize);
        if r <= c {
            picked += 1;
            if (picked + round) % stride == 0 {
                d.upsert_sym(r, c, mirror.vals[i] * 1.02 + 1e-4);
            }
        }
    }
    d
}

/// Deterministic dense query vector (splitmix64-driven values in
/// [-0.5, 0.5)), so query replays reproduce bitwise across runs.
fn query_vector(n: usize, seed: u64) -> Vec<f32> {
    let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    (0..n)
        .map(|_| {
            state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^= z >> 31;
            (z >> 40) as f32 / (1u64 << 24) as f32 - 0.5
        })
        .collect()
}

fn cmd_query(args: &[String]) -> i32 {
    let cmd = Command::new("topk-eigen query", "streaming Top-K SpMV queries against a resident matrix")
        .positional("input", "MatrixMarket file or catalog ID[@scale]")
        .opt("k", "top rows to return per query", Some("10"))
        .opt("queries", "query jobs to run (distinct seeded vectors)", Some("4"))
        .opt("batch", "queries per batched submission — one matrix sweep per batch (1 = independent submits)", Some("1"))
        .opt("replicas", "worker replicas", Some("2"))
        .opt("seed", "seed of the first query vector", Some("1"))
        .opt("precision", "f32|q1.31|q2.30|q1.15", Some("f32"))
        .opt("cus", "SpMV compute units (matrix row shards)", Some("5"))
        .opt("threads", "CU pool worker threads (0 = one per CU)", Some("0"))
        .flag("skip-symmetry-check", "trust the input to be symmetric")
        .flag("quiet", "print only the summary");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let matrix = load_input(m.str("input").map_err(|e| e.to_string())?)?;
        let n = matrix.nrows;
        let k = m.parse_at_least::<usize>("k", 1).map_err(|e| e.to_string())?;
        let queries = m.parse_at_least::<usize>("queries", 1).map_err(|e| e.to_string())?;
        let batch = m.parse_at_least::<usize>("batch", 1).map_err(|e| e.to_string())?;
        let replicas = m.parse_at_least::<usize>("replicas", 1).map_err(|e| e.to_string())?;
        let seed = m.parse::<u64>("seed").map_err(|e| e.to_string())?;
        let opts = SolveOptions {
            precision: parse_precision(m.str("precision").unwrap())?,
            cus: m.parse_at_least::<usize>("cus", 1).map_err(|e| e.to_string())?,
            threads: m.parse::<usize>("threads").map_err(|e| e.to_string())?,
            ..Default::default()
        };
        let svc = EigenService::with_config(ServiceConfig {
            replicas,
            registry: RegistryConfig {
                skip_symmetry_check: m.flag("skip-symmetry-check"),
                ..Default::default()
            },
            ..Default::default()
        });
        println!(
            "querying: n={n} nnz={} k={k} queries={queries} batch={batch} replicas={replicas} precision={}",
            matrix.nnz(),
            opts.precision.name(),
        );
        let handle = svc.register(matrix).map_err(|e| e.to_string())?;
        let t0 = std::time::Instant::now();
        // --batch groups the seeded vectors into submit_query_batch calls:
        // one matrix sweep answers the whole group, bitwise-identical to
        // independent submits.
        let tickets: Vec<_> = if batch > 1 {
            let mut all = Vec::with_capacity(queries);
            let mut q = 0usize;
            while q < queries {
                let b = batch.min(queries - q);
                let xs: Vec<Vec<f32>> =
                    (q..q + b).map(|i| query_vector(n, seed + i as u64)).collect();
                all.extend(svc.submit_query_batch(handle, xs, k, opts.clone()));
                q += b;
            }
            all
        } else {
            (0..queries)
                .map(|q| svc.submit_query(handle, query_vector(n, seed + q as u64), k, opts.clone()))
                .collect()
        };
        let mut ok = 0usize;
        for (id, t) in tickets {
            let r = t.wait();
            match r.outcome {
                Ok(ans) => {
                    ok += 1;
                    if !m.flag("quiet") {
                        println!(
                            "  query {id}: gen={} queued={} took={}",
                            ans.generation,
                            fmt_duration(r.queued_s),
                            fmt_duration(r.query_s),
                        );
                        for e in &ans.entries {
                            println!("    row {:>8}  score {:+.6e}", e.index, e.score);
                        }
                    }
                }
                Err(e) => println!("  query {id} FAILED: {e}"),
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!("answered {ok}/{queries} top-{k} queries in {} -> {:.1} queries/s", fmt_duration(wall), ok as f64 / wall);
        let stats = svc.stats();
        let rstats = svc.registry().stats();
        println!(
            "query path: batches={} batched-queries={} shards-skipped={} rowbound-builds={} rowbound-hits={}",
            stats.query_batches,
            stats.batched_queries,
            stats.shards_skipped,
            rstats.rowbound_builds,
            rstats.rowbound_hits,
        );
        svc.shutdown();
        if ok == queries {
            Ok(0)
        } else {
            Err(format!("{} of {queries} queries failed", queries - ok))
        }
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_ppr(args: &[String]) -> i32 {
    let cmd = Command::new("topk-eigen ppr", "Personalized PageRank on the resident matrix")
        .positional("input", "MatrixMarket file or catalog ID[@scale]")
        .opt("source", "personalization vertex", Some("0"))
        .opt("alpha", "damping factor in (0, 1)", Some("0.85"))
        .opt("tol", "L1-delta convergence tolerance", Some("5e-6"))
        .opt("max-iters", "power-iteration cap", Some("200"))
        .opt("top", "print the N highest-ranked vertices", Some("10"))
        .opt("precision", "f32|q1.31|q2.30|q1.15", Some("f32"))
        .opt("cus", "SpMV compute units (matrix row shards)", Some("5"))
        .opt("threads", "CU pool worker threads (0 = one per CU)", Some("0"))
        .flag("skip-symmetry-check", "trust the input to be symmetric");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let matrix = load_input(m.str("input").map_err(|e| e.to_string())?)?;
        let ppr = PprOptions {
            source: m.parse::<usize>("source").map_err(|e| e.to_string())?,
            alpha: m.parse::<f64>("alpha").map_err(|e| e.to_string())?,
            tol: m.parse::<f64>("tol").map_err(|e| e.to_string())?,
            max_iters: m.parse_at_least::<usize>("max-iters", 1).map_err(|e| e.to_string())?,
        };
        let top = m.parse::<usize>("top").map_err(|e| e.to_string())?;
        let opts = SolveOptions {
            precision: parse_precision(m.str("precision").unwrap())?,
            cus: m.parse_at_least::<usize>("cus", 1).map_err(|e| e.to_string())?,
            threads: m.parse::<usize>("threads").map_err(|e| e.to_string())?,
            ..Default::default()
        };
        let svc = EigenService::with_config(ServiceConfig {
            replicas: 1,
            registry: RegistryConfig {
                skip_symmetry_check: m.flag("skip-symmetry-check"),
                ..Default::default()
            },
            ..Default::default()
        });
        println!(
            "ppr: n={} nnz={} source={} alpha={} tol={:.1e} precision={}",
            matrix.nrows,
            matrix.nnz(),
            ppr.source,
            ppr.alpha,
            ppr.tol,
            opts.precision.name(),
        );
        let handle = svc.register(matrix).map_err(|e| e.to_string())?;
        let (_, t) = svc.submit_ppr(handle, ppr, opts);
        let r = t.wait();
        let ans = r.outcome.map_err(|e| e.to_string())?;
        let p = &ans.ppr;
        println!(
            "{} after {} iterations (L1 delta {:.3e}, {} dangling vertices, gen={}, took {})",
            if p.converged { "converged" } else { "NOT converged" },
            p.iterations,
            p.l1_delta,
            p.dangling,
            ans.generation,
            fmt_duration(r.query_s),
        );
        // Rank the scores with the same bounded heap the query CUs use.
        let mut heap = TopKHeap::new(top.min(p.scores.len()));
        for (i, &s) in p.scores.iter().enumerate() {
            heap.push(i as u32, s);
        }
        for e in heap.into_sorted() {
            println!("  vertex {:>8}  ppr {:.6e}", e.index, e.score);
        }
        svc.shutdown();
        if p.converged {
            Ok(0)
        } else {
            Err(format!("no convergence within {} iterations (last L1 delta {:.3e})", p.iterations, p.l1_delta))
        }
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_catalog() -> i32 {
    println!(
        "{:<6} {:<16} {:>12} {:>14} {:>12} {:>9}  class",
        "ID", "name", "rows", "non-zeros", "sparsity%", "size(GB)"
    );
    for e in graphs::catalog() {
        println!(
            "{:<6} {:<16} {:>12} {:>14} {:>12.3e} {:>9.2}  {:?}",
            e.id,
            e.name,
            e.rows,
            e.nnz,
            e.sparsity_pct(),
            e.size_gb(),
            e.class
        );
    }
    0
}

fn cmd_generate(args: &[String]) -> i32 {
    let cmd = Command::new("topk-eigen generate", "materialize a synthetic catalog twin")
        .positional("id", "catalog ID (see `topk-eigen catalog`)")
        .positional("out", "output .mtx path")
        .opt("scale", "size divisor vs the published graph", Some("64"));
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let id = m.str("id").unwrap();
    let scale: usize = match m.parse("scale") {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let Some(entry) = graphs::catalog().into_iter().find(|e| e.id.eq_ignore_ascii_case(id)) else {
        eprintln!("unknown catalog ID '{id}'");
        return 1;
    };
    let g = entry.generate(scale);
    match topk_eigen::sparse::write_matrix_market(m.str("out").unwrap(), &g) {
        Ok(()) => {
            println!("wrote {} ({} rows, {} nnz)", m.str("out").unwrap(), g.nrows, g.nnz());
            0
        }
        Err(e) => {
            eprintln!("write failed: {e}");
            1
        }
    }
}

fn cmd_export_ooc(args: &[String]) -> i32 {
    let cmd = Command::new("topk-eigen export-ooc", "serialize a matrix into an out-of-core packet directory")
        .positional("input", "MatrixMarket file or catalog ID[@scale]")
        .positional("dir", "output packet directory (created if missing)")
        .opt("precision", "storage format baked into the files: f32|q1.31|q2.30|q1.15", Some("f32"))
        .opt("cus", "SpMV compute units (one chunk file per shard)", Some("5"))
        .opt("partition", "row partition: equal-rows|balanced-nnz", Some("balanced-nnz"))
        .opt("chunk-kb", "chunk payload target in KiB (0 = library default)", Some("0"))
        .flag("skip-symmetry-check", "trust the input to be symmetric");
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let matrix = load_input(m.str("input").map_err(|e| e.to_string())?)?;
        let opts = SolveOptions {
            precision: parse_precision(m.str("precision").unwrap())?,
            cus: m.parse_at_least::<usize>("cus", 1).map_err(|e| e.to_string())?,
            partition: parse_partition(m.str("partition").unwrap())?,
            skip_symmetry_check: m.flag("skip-symmetry-check"),
            ..Default::default()
        };
        let chunk_kb = m.parse::<usize>("chunk-kb").map_err(|e| e.to_string())?;
        let chunk = if chunk_kb == 0 { None } else { Some(chunk_kb << 10) };
        // Prepare resident once (normalize + quantize + shard), then move
        // the engine's exact bits to disk; `solve --ooc` on the directory
        // reproduces this prepare's solves bitwise.
        let mut solver = Solver::new(opts);
        let prep = solver.prepare(&matrix).map_err(|e| e.to_string())?;
        let dir = m.str("dir").unwrap();
        let man = prep.export_ooc(dir, chunk).map_err(|e| format!("{e:#}"))?;
        println!(
            "wrote {dir}: n={} nnz={} shards={} precision={} fro={:.6e}",
            man.nrows,
            man.nnz,
            man.parts.len(),
            man.precision.name(),
            man.fro,
        );
        Ok(0)
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_generate_ooc(args: &[String]) -> i32 {
    let cmd = Command::new(
        "topk-eigen generate-ooc",
        "stream an R-MAT graph directly into a packet directory (never materialized in RAM)",
    )
    .positional("dir", "output packet directory (created if missing)")
    .opt("n", "vertex count (power of two)", Some("4194304"))
    .opt("degree", "directed nnz target per row", Some("8"))
    .opt("a", "R-MAT quadrant probability a", Some("0.57"))
    .opt("b", "R-MAT quadrant probability b", Some("0.19"))
    .opt("c", "R-MAT quadrant probability c", Some("0.19"))
    .opt("seed", "generator seed", Some("42"))
    .opt("precision", "f32|q1.31|q2.30|q1.15", Some("f32"))
    .opt("cus", "shard files (CU stripes of the eventual solve)", Some("5"))
    .opt("chunk-kb", "chunk payload target in KiB (0 = library default)", Some("0"));
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let n = m.parse_at_least::<usize>("n", 2).map_err(|e| e.to_string())?;
        if !n.is_power_of_two() {
            return Err(format!("--n={n}: must be a power of two"));
        }
        let degree = m.parse_at_least::<usize>("degree", 1).map_err(|e| e.to_string())?;
        let a = m.parse::<f64>("a").map_err(|e| e.to_string())?;
        let b = m.parse::<f64>("b").map_err(|e| e.to_string())?;
        let c = m.parse::<f64>("c").map_err(|e| e.to_string())?;
        if !(a > 0.0 && b > 0.0 && c > 0.0 && a + b + c < 1.0) {
            return Err(format!("bad quadrant probabilities a={a} b={b} c={c} (each > 0, sum < 1)"));
        }
        let seed = m.parse::<u64>("seed").map_err(|e| e.to_string())?;
        let precision = parse_precision(m.str("precision").unwrap())?;
        let cus = m.parse_at_least::<usize>("cus", 1).map_err(|e| e.to_string())?;
        let chunk_kb = m.parse::<usize>("chunk-kb").map_err(|e| e.to_string())?;
        let chunk = if chunk_kb == 0 { None } else { Some(chunk_kb << 10) };
        let dir = m.str("dir").unwrap();
        println!(
            "generating: n={n} target-nnz={} precision={} cus={cus} -> {dir}",
            n * degree,
            precision.name(),
        );
        let man = topk_eigen::with_precision!(precision, V => {
            graphs::rmat_packets::<V>(dir, n, n * degree, a, b, c, seed, cus, chunk)
        })
        .map_err(|e| format!("{e:#}"))?;
        println!("wrote {dir}: nnz={} shards={} fro={:.6e}", man.nnz, man.parts.len(), man.fro);
        Ok(0)
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_model(args: &[String]) -> i32 {
    let cmd = Command::new("topk-eigen model", "FPGA timing/resource/power estimate")
        .positional("input", "MatrixMarket file or catalog ID[@scale]")
        .opt("k", "number of eigenpairs", Some("16"))
        .opt("cus", "SpMV compute units", Some("5"))
        .opt("precision", "matrix storage format: f32|q1.31|q2.30|q1.15", Some("f32"));
    let m = match cmd.parse(args) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let run = || -> Result<i32, String> {
        let matrix = load_input(m.str("input").map_err(|e| e.to_string())?)?;
        let k: usize = m.parse("k").map_err(|e| e.to_string())?;
        let cus: usize = m.parse("cus").map_err(|e| e.to_string())?;
        let precision = parse_precision(m.str("precision").unwrap())?;
        let csr = matrix.to_csr();
        let shards = partition_rows_balanced(&csr, cus, PartitionPolicy::EqualRows);
        let model = FpgaTimingModel { cus, ..FpgaTimingModel::for_precision(precision) };
        // Estimate Jacobi steps as (K-1) * ~log2(K)+3 sweeps.
        let steps = (k - 1) * ((k as f64).log2().ceil() as usize + 3);
        let t = model.solve_time(csr.nrows, &shards, k, ReorthPolicy::EveryN(2), steps);
        println!(
            "FPGA model (U280 @225MHz, {cus} CUs, K={k}, {} values, {} nnz/line):",
            precision.name(),
            model.packet_nnz
        );
        println!("  spmv   = {}", fmt_duration(t.spmv_s));
        println!("  memory = {}", fmt_duration(t.memory_s));
        println!("  vector = {}", fmt_duration(t.vector_s));
        println!("  reorth = {}", fmt_duration(t.reorth_s));
        println!("  jacobi = {}", fmt_duration(t.jacobi_s));
        println!(
            "  total  = {}  (read bw {:.2} GB/s)",
            fmt_duration(t.total_s()),
            model.effective_read_gbps(&shards)
        );
        let lanczos_res = topk_eigen::fpga::lanczos_core_resources(cus);
        let (lut, ff, bram, uram, dsp) = SlrBudget::utilization_pct(lanczos_res);
        println!("  SLR0 (Lanczos): LUT {lut:.0}% FF {ff:.0}% BRAM {bram:.0}% URAM {uram:.0}% DSP {dsp:.0}%");
        let kc = k.max(4).next_power_of_two();
        let jk = topk_eigen::fpga::jacobi_core_resources(kc);
        let (lut, ff, _, _, dsp) = SlrBudget::utilization_pct(jk);
        println!("  SLR1 (Jacobi K={kc}): LUT {lut:.0}% FF {ff:.0}% DSP {dsp:.0}%");
        let p = PowerModel::default().compare(t.total_s(), t.total_s() * 6.22);
        println!(
            "  power: {:.0}W card, {:.3}J per solve; at paper-geomean CPU time: perf/W {:.0}x (card), {:.0}x (with host)",
            PowerModel::default().fpga_w,
            p.fpga_energy_j,
            p.perf_per_watt_gain,
            p.perf_per_watt_gain_with_host
        );
        Ok(0)
    };
    match run() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_artifacts() -> i32 {
    use topk_eigen::runtime::{artifacts_dir, ArtifactRegistry};
    let dir = artifacts_dir();
    println!("artifact dir: {}", dir.display());
    let mut missing = 0;
    for f in ArtifactRegistry::all_files() {
        let p = dir.join(&f);
        let ok = p.is_file();
        println!("  [{}] {f}", if ok { "ok" } else { "MISSING" });
        if !ok {
            missing += 1;
        }
    }
    if missing > 0 {
        eprintln!("{missing} artifacts missing — run `make artifacts`");
        1
    } else {
        0
    }
}

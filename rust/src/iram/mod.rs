//! Restarted Lanczos baseline — the CPU comparator (§V).
//!
//! The paper benchmarks against multi-threaded ARPACK, which implements the
//! Implicitly Restarted Arnoldi Method; for symmetric operators IRAM with
//! exact shifts is mathematically equivalent to the **thick-restart
//! Lanczos** method implemented here (Wu & Simon 2000; same restart
//! polynomial, same convergence behaviour, numerically more robust). The
//! SpMV runs through the same [`Operator`] abstraction as our solver, so
//! CPU-vs-FPGA comparisons are like-for-like on identical matrices:
//! multi-threaded via [`crate::lanczos::ShardedSpmv`] exactly as ARPACK
//! parallelizes its matvecs.
//!
//! Unlike the paper's single-pass solver (K SpMVs total), a restarted
//! method performs `ncv` SpMVs per restart cycle until Ritz pairs converge
//! — this is precisely the work gap the paper's Fig 9 speedups come from,
//! so the baseline must be an honest, tuned implementation: full
//! reorthogonalization (ARPACK default for symmetric drivers), exact-shift
//! thick restarts, locking of converged pairs via the standard residual
//! bound `|beta_m * y[m-1]|`.

use crate::lanczos::Operator;
use crate::linalg::{self, qr_algorithm_symmetric, DenseMatrix};

/// Options for the restarted solver (names follow ARPACK's `dsaupd`).
#[derive(Clone, Debug)]
pub struct IramOptions {
    /// Number of wanted eigenpairs (largest magnitude).
    pub k: usize,
    /// Krylov subspace dimension per cycle (ARPACK `ncv`); defaults to
    /// `max(2k+1, 20)` capped to `n`, ARPACK's recommended sizing.
    pub ncv: Option<usize>,
    /// Relative residual tolerance for convergence.
    pub tol: f64,
    /// Maximum restart cycles.
    pub max_restarts: usize,
    /// Seed for the random start vector.
    pub seed: u64,
}

impl Default for IramOptions {
    fn default() -> Self {
        Self { k: 8, ncv: None, tol: 1e-8, max_restarts: 300, seed: 7 }
    }
}

/// Result of a restarted-Lanczos solve.
#[derive(Clone, Debug)]
pub struct IramResult {
    /// Converged eigenvalues (decreasing magnitude).
    pub eigenvalues: Vec<f64>,
    /// Matching eigenvectors (unit norm, length n).
    pub eigenvectors: Vec<Vec<f32>>,
    /// Residual-norm estimate per pair.
    pub residuals: Vec<f64>,
    /// Restart cycles used.
    pub restarts: usize,
    /// Total SpMV applications (the cost driver for Fig 9).
    pub spmv_count: usize,
    /// Whether every wanted pair met the tolerance.
    pub converged: bool,
}

/// Orthogonalize `w` against every row of `basis` (two MGS passes —
/// "twice is enough", the ARPACK/Kahan rule).
fn full_orth(w: &mut [f32], basis: &[Vec<f32>]) {
    for _ in 0..2 {
        for b in basis {
            let proj = linalg::dot(w, b);
            linalg::axpy(-(proj as f32), b, w);
        }
    }
}

/// Thick-restart Lanczos, ARPACK-equivalent for symmetric matrices.
pub fn iram<O: Operator + ?Sized>(op: &O, opts: &IramOptions) -> IramResult {
    let n = op.n();
    let k = opts.k;
    assert!(k >= 1 && k < n, "need 1 <= k < n");
    let ncv = opts.ncv.unwrap_or_else(|| (2 * k + 1).max(20)).min(n);
    assert!(ncv > k, "ncv must exceed k");

    let mut rng = crate::util::rng::Pcg64::new(opts.seed);
    // Basis rows v_0..v_{m-1}; T held dense (arrowhead after restarts).
    let mut basis: Vec<Vec<f32>> = Vec::with_capacity(ncv);
    let mut t = DenseMatrix::zeros(ncv, ncv);
    let mut spmv_count = 0usize;

    // Random unit start (ARPACK uses a random resid vector).
    let mut v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    linalg::normalize(&mut v);
    basis.push(v);

    let mut kept = 0usize; // locked/retained rows after the last restart
    let mut w = vec![0.0f32; n];
    let mut restarts = 0usize;

    loop {
        // --- Expand the factorization from `basis.len()` up to ncv rows.
        while basis.len() < ncv {
            let j = basis.len() - 1;
            op.apply(&basis[j], &mut w);
            spmv_count += 1;
            if j == kept && kept > 0 {
                // First expansion step after a thick restart: w couples to
                // every retained Ritz row through the arrowhead entries.
                for i in 0..kept {
                    t[(i, j)] = t[(i, j)]; // arrowhead already recorded
                }
            }
            // Rayleigh coefficient.
            let alpha = linalg::dot(&w, &basis[j]);
            t[(j, j)] = alpha;
            // Full orthogonalization against the whole basis (covers both
            // the three-term terms and the arrowhead coupling).
            full_orth(&mut w, &basis);
            let beta = linalg::norm2(&w);
            if beta < 1e-12 {
                // Invariant subspace: restart the residual randomly.
                let mut r: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                full_orth(&mut r, &basis);
                if linalg::normalize(&mut r) == 0.0 {
                    break; // space exhausted (n small)
                }
                basis.push(r);
                // beta entry stays 0: T block-decouples, which is correct.
                continue;
            }
            if basis.len() < ncv {
                t[(j, j + 1)] = beta;
                t[(j + 1, j)] = beta;
            }
            let inv = (1.0 / beta) as f32;
            let next: Vec<f32> = w.iter().map(|&x| x * inv).collect();
            basis.push(next);
        }
        let m = basis.len();

        // --- Rayleigh-Ritz on the m x m projected matrix.
        let mut tm = DenseMatrix::zeros(m, m);
        for i in 0..m {
            for j in 0..m {
                tm[(i, j)] = t[(i, j)];
            }
        }
        // beta_m: norm of the next residual direction (recompute).
        op.apply(&basis[m - 1], &mut w);
        spmv_count += 1;
        let alpha_last = linalg::dot(&w, &basis[m - 1]);
        tm[(m - 1, m - 1)] = alpha_last;
        full_orth(&mut w, &basis);
        let beta_m = linalg::norm2(&w);

        let (theta, y) = qr_algorithm_symmetric(&tm, 1e-13, 2000);

        // Residual bounds |beta_m * y[m-1, i]| for the top-k Ritz pairs.
        let mut residuals: Vec<f64> = (0..k).map(|i| (beta_m * y[(m - 1, i)]).abs()).collect();
        let converged = residuals
            .iter()
            .zip(&theta)
            .all(|(r, th)| *r <= opts.tol * th.abs().max(1e-30));

        restarts += 1;
        if converged || restarts >= opts.max_restarts {
            // Lift the top-k Ritz vectors to R^n.
            let mut eigenvectors = Vec::with_capacity(k);
            for i in 0..k {
                let coeffs = y.col(i);
                let mut q = vec![0.0f32; n];
                for (c, b) in coeffs.iter().zip(&basis) {
                    linalg::axpy(*c as f32, b, &mut q);
                }
                linalg::normalize(&mut q);
                eigenvectors.push(q);
            }
            // True residuals ||Mv - lambda v|| for reporting.
            for i in 0..k {
                op.apply(&eigenvectors[i], &mut w);
                spmv_count += 1;
                let mut r2 = 0.0f64;
                for (wi, vi) in w.iter().zip(&eigenvectors[i]) {
                    let d = *wi as f64 - theta[i] * *vi as f64;
                    r2 += d * d;
                }
                residuals[i] = r2.sqrt();
            }
            return IramResult {
                eigenvalues: theta[..k].to_vec(),
                eigenvectors,
                residuals,
                restarts,
                spmv_count,
                converged,
            };
        }

        // --- Thick restart: retain the top-k Ritz pairs + the residual.
        let keep = k;
        let mut new_basis: Vec<Vec<f32>> = Vec::with_capacity(ncv);
        for i in 0..keep {
            let coeffs = y.col(i);
            let mut q = vec![0.0f32; n];
            for (c, b) in coeffs.iter().zip(&basis) {
                linalg::axpy(*c as f32, b, &mut q);
            }
            linalg::normalize(&mut q);
            new_basis.push(q);
        }
        // Residual direction becomes row keep.
        let inv = (1.0 / beta_m) as f32;
        let mut r: Vec<f32> = w.iter().map(|&x| x * inv).collect();
        full_orth(&mut r, &new_basis);
        linalg::normalize(&mut r);
        new_basis.push(r);

        // New projected matrix: diag(theta_0..theta_{k-1}) with arrowhead
        // coupling s_i = beta_m * y[m-1, i] in row/col `keep`.
        t = DenseMatrix::zeros(ncv, ncv);
        for i in 0..keep {
            t[(i, i)] = theta[i];
            let s = beta_m * y[(m - 1, i)];
            t[(i, keep)] = s;
            t[(keep, i)] = s;
        }
        basis = new_basis;
        kept = keep;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphs;
    use crate::sparse::CooMatrix;

    fn diag(vals: &[f32]) -> crate::sparse::CsrMatrix {
        let n = vals.len();
        let mut m = CooMatrix::new(n, n);
        for (i, &v) in vals.iter().enumerate() {
            m.push(i, i, v);
        }
        m.to_csr()
    }

    #[test]
    fn finds_dominant_diagonal_eigenvalues() {
        let mut vals: Vec<f32> = (0..200).map(|i| 0.001 * i as f32).collect();
        vals[7] = 0.95;
        vals[13] = -0.9;
        vals[99] = 0.85;
        let m = diag(&vals);
        let r = iram(&m, &IramOptions { k: 3, tol: 1e-9, ..Default::default() });
        assert!(r.converged, "restarts={}", r.restarts);
        assert!((r.eigenvalues[0] - 0.95).abs() < 1e-6, "{:?}", r.eigenvalues);
        assert!((r.eigenvalues[1] - -0.9).abs() < 1e-6);
        assert!((r.eigenvalues[2] - 0.85).abs() < 1e-6);
        // Eigenvector of lambda_0 is e_7.
        assert!(r.eigenvectors[0][7].abs() > 0.999);
    }

    #[test]
    fn residuals_meet_tolerance_on_graph() {
        let mut coo = graphs::rmat(1 << 9, 6 << 9, 0.57, 0.19, 0.19, 11);
        crate::sparse::normalize_frobenius(&mut coo);
        let m = coo.to_csr();
        let r = iram(&m, &IramOptions { k: 6, tol: 1e-8, ..Default::default() });
        assert!(r.converged);
        for (i, res) in r.residuals.iter().enumerate() {
            assert!(*res < 1e-6, "pair {i} residual {res} (lambda {})", r.eigenvalues[i]);
        }
        // Magnitude ordering.
        for w in r.eigenvalues.windows(2) {
            assert!(w[0].abs() >= w[1].abs() - 1e-10);
        }
    }

    #[test]
    fn matches_single_pass_lanczos_on_easy_spectrum() {
        let mut coo = graphs::mesh2d(24, 24, 0.9, 0.01, 5);
        crate::sparse::normalize_frobenius(&mut coo);
        let m = coo.to_csr();
        let ir = iram(&m, &IramOptions { k: 4, tol: 1e-9, ..Default::default() });
        let lz = crate::lanczos::lanczos(
            &m,
            &crate::lanczos::LanczosOptions {
                k: 24,
                reorth: crate::lanczos::ReorthPolicy::Every,
                ..Default::default()
            },
        );
        let je = crate::jacobi::jacobi_eigen(&lz.tridiag, crate::jacobi::JacobiMode::Cyclic, 1e-12);
        for i in 0..3 {
            assert!(
                (ir.eigenvalues[i] - je.eigenvalues[i]).abs() < 2e-3,
                "pair {i}: iram {} vs lanczos+jacobi {}",
                ir.eigenvalues[i],
                je.eigenvalues[i]
            );
        }
    }

    #[test]
    fn spmv_count_exceeds_single_pass() {
        // The restarted baseline must do more SpMVs than K — that gap is
        // the paper's speedup source.
        let mut coo = graphs::rmat(1 << 8, 5 << 8, 0.57, 0.19, 0.19, 2);
        crate::sparse::normalize_frobenius(&mut coo);
        let m = coo.to_csr();
        let r = iram(&m, &IramOptions { k: 8, tol: 1e-8, ..Default::default() });
        assert!(r.spmv_count > 8, "spmv_count = {}", r.spmv_count);
    }

    #[test]
    #[should_panic(expected = "need 1 <= k < n")]
    fn k_bounds_checked() {
        let m = diag(&[1.0, 2.0]);
        iram(&m, &IramOptions { k: 2, ..Default::default() });
    }
}

//! PJRT runtime — the L3 ↔ L2/L1 bridge.
//!
//! `make artifacts` runs `python/compile/aot.py` once, lowering the JAX
//! model (which calls the Pallas kernels) to **HLO text** under
//! `artifacts/`. This module loads those files with
//! `HloModuleProto::from_text_file`, compiles them on the PJRT CPU client,
//! and executes them from the request path — Python is never involved at
//! runtime.
//!
//! Artifacts are compiled for fixed shapes (XLA requirement), so the
//! registry exposes *variants* (`spmv_n4096_nnz65536`, `jacobi_k8`, ...)
//! and [`ArtifactRegistry::pick_spmv`] selects the smallest variant that
//! fits a workload; inputs are zero-padded up to the variant shape (padding
//! entries scatter `0.0 * x[0]` into row 0 — a no-op by construction).
//!
//! ## Feature gating
//!
//! The whole execution path sits behind the **`pjrt`** cargo feature so the
//! default build is hermetic (no Python/XLA toolchain). Without the
//! feature, [`Runtime`], [`PjrtSpmv`] and [`PjrtJacobi`] are pure-Rust
//! stubs (see the private `stub` module) that resolve the artifact
//! directory, reproduce the shape checks, and report the engine as
//! unavailable — the coordinator then falls back to the native sharded
//! engine. The shape registry ([`ArtifactRegistry`], [`SpmvVariant`]) and
//! [`artifacts_dir`] are always available: the scheduler and the FPGA model
//! use them independently of execution.

#[cfg(feature = "pjrt")]
mod jacobi;
#[cfg(feature = "pjrt")]
mod spmv;
#[cfg(not(feature = "pjrt"))]
mod stub;

#[cfg(feature = "pjrt")]
pub use jacobi::PjrtJacobi;
#[cfg(feature = "pjrt")]
pub use spmv::PjrtSpmv;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Module, PjrtJacobi, PjrtSpmv, Runtime};

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct Module {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact path (for diagnostics).
    pub path: PathBuf,
}

#[cfg(feature = "pjrt")]
impl Module {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(args)
            .with_context(|| format!("executing {}", self.path.display()))?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with device-resident buffers (used on the hot path to keep
    /// the matrix uploaded once); returns raw output buffers.
    pub fn run_buffers(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<xla::PjRtBuffer>>> {
        Ok(self.exe.execute_b(args)?)
    }
}

/// PJRT client + compiled-module cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<Module>>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// CPU PJRT client rooted at the artifact directory (`TOPK_ARTIFACTS`
    /// env var, default `artifacts/`).
    pub fn cpu() -> Result<Self> {
        let dir = artifacts_dir();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, dir, cache: Mutex::new(HashMap::new()) })
    }

    /// The artifact directory in use.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Underlying PJRT client (for buffer uploads).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile an artifact by file name (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Module>> {
        let path = self.dir.join(name);
        if let Some(m) = self.cache.lock().unwrap().get(&path) {
            return Ok(std::sync::Arc::clone(m));
        }
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading HLO text {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        let module = std::sync::Arc::new(Module { exe, path: path.clone() });
        self.cache.lock().unwrap().insert(path, std::sync::Arc::clone(&module));
        Ok(module)
    }

    /// Upload an f32 slice as a device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 slice as a device buffer.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }
}

/// Artifact directory resolution: `TOPK_ARTIFACTS` env var, else
/// `./artifacts` relative to the working directory, else next to the
/// executable.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(d) = std::env::var("TOPK_ARTIFACTS") {
        return PathBuf::from(d);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.is_dir() {
        return cwd;
    }
    // Fall back to the crate root (useful under `cargo test` from subdirs).
    if let Ok(manifest) = std::env::var("CARGO_MANIFEST_DIR") {
        let p = Path::new(&manifest).join("artifacts");
        if p.is_dir() {
            return p;
        }
    }
    cwd
}

/// The shape variants `aot.py` emits, mirrored here. Kept in one place so
/// the build pipeline and the registry cannot drift silently (the
/// integration test asserts every listed artifact exists after
/// `make artifacts`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpmvVariant {
    /// Padded vector length.
    pub n: usize,
    /// Padded nnz capacity.
    pub nnz: usize,
}

impl SpmvVariant {
    /// Artifact file name for the plain SpMV kernel.
    pub fn spmv_file(&self) -> String {
        format!("spmv_n{}_nnz{}.hlo.txt", self.n, self.nnz)
    }
    /// Artifact file name for the fused Lanczos step.
    pub fn lanczos_step_file(&self) -> String {
        format!("lanczos_step_n{}_nnz{}.hlo.txt", self.n, self.nnz)
    }
}

/// Registry of available artifact shapes.
pub struct ArtifactRegistry;

impl ArtifactRegistry {
    /// SpMV variants emitted by `aot.py` (keep sorted by capacity).
    pub const SPMV_VARIANTS: [SpmvVariant; 3] = [
        SpmvVariant { n: 1024, nnz: 20_480 },
        SpmvVariant { n: 4096, nnz: 81_920 },
        SpmvVariant { n: 16_384, nnz: 327_680 },
    ];

    /// Jacobi core sizes emitted by `aot.py` (mirrors the paper's multi-K
    /// bitstream: cores for K = 4, 8, 16, 32).
    pub const JACOBI_KS: [usize; 4] = [4, 8, 16, 32];

    /// Smallest SpMV variant that fits `(n, nnz)`.
    pub fn pick_spmv(n: usize, nnz: usize) -> Option<SpmvVariant> {
        Self::SPMV_VARIANTS.iter().copied().find(|v| v.n >= n && v.nnz >= nnz)
    }

    /// Smallest Jacobi core size >= `k`.
    pub fn pick_jacobi(k: usize) -> Option<usize> {
        Self::JACOBI_KS.iter().copied().find(|&c| c >= k)
    }

    /// Jacobi artifact file name.
    pub fn jacobi_file(k_core: usize) -> String {
        format!("jacobi_k{k_core}.hlo.txt")
    }

    /// All artifact file names the build must produce.
    pub fn all_files() -> Vec<String> {
        let mut v = Vec::new();
        for s in Self::SPMV_VARIANTS {
            v.push(s.spmv_file());
            v.push(s.lanczos_step_file());
        }
        for k in Self::JACOBI_KS {
            v.push(Self::jacobi_file(k));
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_selection_picks_smallest_fit() {
        let v = ArtifactRegistry::pick_spmv(1000, 10_000).unwrap();
        assert_eq!(v, SpmvVariant { n: 1024, nnz: 20_480 });
        let v = ArtifactRegistry::pick_spmv(1025, 10_000).unwrap();
        assert_eq!(v.n, 4096);
        let v = ArtifactRegistry::pick_spmv(5000, 200_000).unwrap();
        assert_eq!(v.nnz, 327_680);
        assert!(ArtifactRegistry::pick_spmv(1 << 20, 1).is_none());
    }

    #[test]
    fn jacobi_core_selection() {
        assert_eq!(ArtifactRegistry::pick_jacobi(8), Some(8));
        assert_eq!(ArtifactRegistry::pick_jacobi(12), Some(16));
        assert_eq!(ArtifactRegistry::pick_jacobi(24), Some(32));
        assert_eq!(ArtifactRegistry::pick_jacobi(33), None);
    }

    #[test]
    fn file_names_are_stable() {
        let v = SpmvVariant { n: 4096, nnz: 65_536 };
        assert_eq!(v.spmv_file(), "spmv_n4096_nnz65536.hlo.txt");
        assert_eq!(v.lanczos_step_file(), "lanczos_step_n4096_nnz65536.hlo.txt");
        assert_eq!(ArtifactRegistry::jacobi_file(8), "jacobi_k8.hlo.txt");
        assert_eq!(ArtifactRegistry::all_files().len(), 10);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_constructs_and_reports_loads_unavailable() {
        let rt = Runtime::cpu().expect("stub runtime always constructs");
        assert!(rt.dir().as_os_str().len() > 0);
        let err = rt.load("spmv_n1024_nnz20480.hlo.txt").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("pjrt"), "error should name the missing feature: {msg}");
        assert!(msg.contains("spmv_n1024_nnz20480"), "error should name the artifact: {msg}");
    }
}

//! Phase-2 through a PJRT-compiled Jacobi artifact.
//!
//! Mirrors the paper's fixed-K Jacobi cores: each artifact is compiled for
//! a specific core size K (4/8/16/32); a request with smaller k runs on
//! the next core up with zero padding (a core "can compute a lower amount
//! of eigenvalues without a reconfiguration", §IV-C). Padding introduces
//! exact zero eigenpairs supported on the padded coordinates, which are
//! filtered out on return.

use crate::linalg::{DenseMatrix, Tridiagonal};
use crate::runtime::{ArtifactRegistry, Module, Runtime};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// A compiled fixed-K Jacobi core.
pub struct PjrtJacobi {
    module: Arc<Module>,
    /// Core size (the artifact's K).
    pub k_core: usize,
}

impl PjrtJacobi {
    /// Load the smallest core fitting `k`.
    pub fn new(runtime: &Runtime, k: usize) -> Result<Self> {
        let k_core = ArtifactRegistry::pick_jacobi(k)
            .ok_or_else(|| anyhow!("no Jacobi artifact core fits k={k} (max 32)"))?;
        let module = runtime.load(&ArtifactRegistry::jacobi_file(k_core))?;
        Ok(Self { module, k_core })
    }

    /// Diagonalize `t`, returning `(eigenvalues, eigenvector-columns)`
    /// sorted by decreasing magnitude, truncated to `t.k()` genuine pairs.
    pub fn eigen(&self, t: &Tridiagonal) -> Result<(Vec<f64>, DenseMatrix)> {
        let k = t.k();
        anyhow::ensure!(k <= self.k_core, "tridiagonal k={k} exceeds core {}", self.k_core);
        let kc = self.k_core;
        let mut alpha = vec![0.0f32; kc];
        let mut beta = vec![0.0f32; kc];
        for i in 0..k {
            alpha[i] = t.alpha[i] as f32;
        }
        for i in 0..k.saturating_sub(1) {
            beta[i] = t.beta[i] as f32;
        }
        let a = xla::Literal::vec1(&alpha);
        let b = xla::Literal::vec1(&beta);
        let out = self.module.run(&[a, b])?;
        anyhow::ensure!(out.len() == 2, "jacobi artifact must return (eigvals, eigvecs)");
        let ev: Vec<f32> = out[0].to_vec()?;
        let vecs_flat: Vec<f32> = out[1].to_vec()?;
        anyhow::ensure!(ev.len() == kc && vecs_flat.len() == kc * kc, "unexpected output shapes");

        // Filter padded pairs: a padded eigenpair's vector is supported on
        // coordinates >= k. Keep pairs with majority support inside 0..k.
        let mut kept: Vec<(f64, Vec<f64>)> = Vec::with_capacity(k);
        for j in 0..kc {
            let col: Vec<f64> = (0..kc).map(|i| vecs_flat[i * kc + j] as f64).collect();
            let head: f64 = col[..k].iter().map(|x| x * x).sum();
            let total: f64 = col.iter().map(|x| x * x).sum();
            if total > 0.0 && head / total > 0.5 {
                kept.push((ev[j] as f64, col[..k].to_vec()));
            }
        }
        anyhow::ensure!(kept.len() >= k, "padding filter kept {} of {k} pairs", kept.len());
        kept.truncate(k); // already sorted by |lambda| desc in the artifact
        let eigenvalues: Vec<f64> = kept.iter().map(|(l, _)| *l).collect();
        let mut eigenvectors = DenseMatrix::zeros(k, k);
        for (j, (_, col)) in kept.iter().enumerate() {
            // Renormalize after truncating the (tiny) padded components.
            let norm: f64 = col.iter().map(|x| x * x).sum::<f64>().sqrt();
            for i in 0..k {
                eigenvectors[(i, j)] = col[i] / norm.max(1e-300);
            }
        }
        Ok((eigenvalues, eigenvectors))
    }
}

//! Device-resident SpMV through a compiled Pallas/XLA artifact.
//!
//! Mirrors the hardware residency model: the COO matrix is uploaded to the
//! device **once** (the FPGA streams it from HBM every iteration; PJRT
//! keeps it in device buffers), and each `apply` uploads only the dense
//! vector — exactly the traffic pattern of the paper's iterative design
//! ("multiple iterations without communication from device to host" except
//! the per-iteration vector, §IV-B).

use crate::lanczos::Operator;
use crate::runtime::{ArtifactRegistry, Module, Runtime, SpmvVariant};
use crate::sparse::CooMatrix;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// An [`Operator`] backed by a PJRT-compiled SpMV artifact.
pub struct PjrtSpmv {
    module: Arc<Module>,
    rows: xla::PjRtBuffer,
    cols: xla::PjRtBuffer,
    vals: xla::PjRtBuffer,
    runtime: Arc<Runtime>,
    variant: SpmvVariant,
    n: usize,
    nnz: usize,
}

// SAFETY: the xla PJRT handles are thread-safe at the C++ level (PJRT CPU
// client is internally synchronized); the raw pointers lack auto-traits only.
unsafe impl Send for PjrtSpmv {}
// SAFETY: as above — shared access goes through the internally synchronized
// PJRT client, so `&PjrtSpmv` is safe to share across threads.
unsafe impl Sync for PjrtSpmv {}

impl PjrtSpmv {
    /// Load the best-fitting artifact for `coo` and upload the (padded)
    /// matrix to the device.
    pub fn new(runtime: Arc<Runtime>, coo: &CooMatrix) -> Result<Self> {
        assert_eq!(coo.nrows, coo.ncols, "operator must be square");
        let variant = ArtifactRegistry::pick_spmv(coo.nrows, coo.nnz())
            .ok_or_else(|| anyhow!("no SpMV artifact fits n={} nnz={}", coo.nrows, coo.nnz()))?;
        let module = runtime.load(&variant.spmv_file())?;

        // Pad to the variant shape. Padding entries are (row=0, col=0,
        // val=0.0): they scatter 0 into y[0] — a no-op.
        let mut rows = vec![0i32; variant.nnz];
        let mut cols = vec![0i32; variant.nnz];
        let mut vals = vec![0f32; variant.nnz];
        for i in 0..coo.nnz() {
            rows[i] = coo.rows[i] as i32;
            cols[i] = coo.cols[i] as i32;
            vals[i] = coo.vals[i];
        }
        let rows = runtime.upload_i32(&rows, &[variant.nnz])?;
        let cols = runtime.upload_i32(&cols, &[variant.nnz])?;
        let vals = runtime.upload_f32(&vals, &[variant.nnz])?;
        Ok(Self { module, rows, cols, vals, runtime, variant, n: coo.nrows, nnz: coo.nnz() })
    }

    /// The artifact variant in use.
    pub fn variant(&self) -> SpmvVariant {
        self.variant
    }

    /// Raw padded apply: `x_pad` must have length `variant.n`; returns the
    /// padded output (length `variant.n`).
    fn apply_padded(&self, x_pad: &[f32]) -> Result<Vec<f32>> {
        let x = self.runtime.upload_f32(x_pad, &[self.variant.n])?;
        let out = self.module.run_buffers(&[&self.rows, &self.cols, &self.vals, &x])?;
        let lit = out[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let y = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        Ok(y.to_vec::<f32>()?)
    }
}

impl Operator for PjrtSpmv {
    fn n(&self) -> usize {
        self.n
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn apply(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        let mut x_pad = vec![0.0f32; self.variant.n];
        x_pad[..self.n].copy_from_slice(x);
        let out = self.apply_padded(&x_pad).expect("PJRT SpMV execution failed");
        y.copy_from_slice(&out[..self.n]);
    }
}

// Tests that need built artifacts live in rust/tests/pjrt_integration.rs
// (they skip with a notice when `make artifacts` has not run).

//! Pure-Rust stand-ins for the PJRT runtime when the `pjrt` feature is off.
//!
//! These keep the rest of the crate (coordinator, CLI, benches, tests)
//! compiling against one API regardless of the feature set. They perform
//! the same *host-side* validation as the real implementations — artifact
//! directory resolution, shape-registry fit checks — and then report the
//! engine as unavailable, so every caller exercises its fallback path (the
//! coordinator logs a warning and routes Lanczos through the native
//! [`crate::sparse::ShardedSpmv`] engine).

use crate::lanczos::Operator;
use crate::linalg::{DenseMatrix, Tridiagonal};
use crate::runtime::{artifacts_dir, ArtifactRegistry, SpmvVariant};
use crate::sparse::CooMatrix;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Uninhabited type: proves stub handles can never be constructed, so the
/// unreachable method bodies below need no `unsafe`/`panic!`.
enum Never {}

/// (stub) A compiled artifact. Never constructed without the `pjrt`
/// feature; exists so `Runtime::load`'s signature is feature-independent.
pub struct Module {
    _never: Never,
    /// Artifact path (for diagnostics).
    pub path: PathBuf,
}

/// (stub) PJRT client placeholder: resolves the artifact directory and
/// reports every load as unavailable.
pub struct Runtime {
    dir: PathBuf,
}

impl Runtime {
    /// Build the stub runtime. Always succeeds — it holds only the
    /// artifact directory; failures surface at [`Runtime::load`].
    pub fn cpu() -> Result<Self> {
        Ok(Self { dir: artifacts_dir() })
    }

    /// The artifact directory in use.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Always fails: compiling artifacts requires the `pjrt` feature.
    pub fn load(&self, name: &str) -> Result<Arc<Module>> {
        Err(anyhow!(
            "cannot load {}: topk-eigen was built without the `pjrt` feature \
             (rebuild with `--features pjrt` and real XLA bindings to execute artifacts)",
            self.dir.join(name).display()
        ))
    }
}

/// (stub) PJRT-backed SpMV operator. [`PjrtSpmv::new`] reproduces the real
/// constructor's shape checks, then reports the engine unavailable so the
/// coordinator falls back to the native sharded engine.
pub struct PjrtSpmv {
    _never: Never,
}

impl PjrtSpmv {
    /// Mirror the real constructor: validate the matrix shape against the
    /// artifact registry, then fail with a feature-gate message.
    pub fn new(_runtime: Arc<Runtime>, coo: &CooMatrix) -> Result<Self> {
        assert_eq!(coo.nrows, coo.ncols, "operator must be square");
        ArtifactRegistry::pick_spmv(coo.nrows, coo.nnz())
            .ok_or_else(|| anyhow!("no SpMV artifact fits n={} nnz={}", coo.nrows, coo.nnz()))?;
        Err(anyhow!("PJRT SpMV engine requires the `pjrt` feature"))
    }

    /// The artifact variant in use (unreachable: stubs are never built).
    pub fn variant(&self) -> SpmvVariant {
        unreachable!("stub PjrtSpmv is never constructed")
    }
}

impl Operator for PjrtSpmv {
    fn n(&self) -> usize {
        unreachable!("stub PjrtSpmv is never constructed")
    }
    fn nnz(&self) -> usize {
        unreachable!("stub PjrtSpmv is never constructed")
    }
    fn apply(&self, _x: &[f32], _y: &mut [f32]) {
        unreachable!("stub PjrtSpmv is never constructed")
    }
}

/// (stub) PJRT-backed fixed-K Jacobi core.
pub struct PjrtJacobi {
    _never: Never,
}

impl PjrtJacobi {
    /// Mirror the real constructor: validate `k` against the core registry,
    /// then fail with a feature-gate message.
    pub fn new(_runtime: &Runtime, k: usize) -> Result<Self> {
        ArtifactRegistry::pick_jacobi(k)
            .ok_or_else(|| anyhow!("no Jacobi artifact core fits k={k} (max 32)"))?;
        Err(anyhow!("PJRT Jacobi engine requires the `pjrt` feature"))
    }

    /// The loaded core size (unreachable: stubs are never built).
    pub fn k_core(&self) -> usize {
        unreachable!("stub PjrtJacobi is never constructed")
    }

    /// Diagonalize `t` (unreachable: stubs are never built).
    pub fn eigen(&self, _t: &Tridiagonal) -> Result<(Vec<f64>, DenseMatrix)> {
        unreachable!("stub PjrtJacobi is never constructed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphs;

    #[test]
    fn stub_spmv_reports_fit_errors_like_the_real_path() {
        let rt = Arc::new(Runtime::cpu().unwrap());
        // Oversized: the registry check must fire first, matching the real
        // constructor's error text (tests/end_to_end.rs relies on it).
        let mut big = CooMatrix::new(1 << 20, 1 << 20);
        big.push(0, 0, 1.0);
        let err = PjrtSpmv::new(Arc::clone(&rt), &big).unwrap_err();
        assert!(format!("{err}").contains("no SpMV artifact"), "{err}");
        // In-range: the stub still refuses, naming the feature gate.
        let small = graphs::erdos_renyi(64, 256, 1);
        let err = PjrtSpmv::new(rt, &small).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    #[test]
    fn stub_jacobi_reports_fit_errors_like_the_real_path() {
        let rt = Runtime::cpu().unwrap();
        let err = PjrtJacobi::new(&rt, 40).unwrap_err();
        assert!(format!("{err}").contains("no Jacobi artifact"), "{err}");
        let err = PjrtJacobi::new(&rt, 8).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}

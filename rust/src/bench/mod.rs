//! Benchmark harness (offline substitute for `criterion`).
//!
//! Each `cargo bench` target is a `harness = false` binary that builds a
//! [`BenchSuite`], registers measurements, and calls [`BenchSuite::finish`]
//! to print a table and (optionally, `TOPK_BENCH_JSON=path`) dump a JSON
//! report. Warmup + repeated timed iterations with mean/stddev/median,
//! like criterion's default estimator but with an explicit row model so
//! a bench can also report *derived* quantities (speedups, error norms,
//! modelled FPGA times) — which is what reproducing paper tables needs.

use crate::util::json::Json;
use crate::util::timer::{fmt_duration, Stats};
use std::time::Instant;

/// Measurement configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Warmup iterations (not recorded).
    pub warmup: usize,
    /// Recorded iterations.
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Keep defaults modest: paper-scale workloads run seconds each.
        // Override per-call or with TOPK_BENCH_ITERS.
        let iters = std::env::var("TOPK_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
        Self { warmup: 1, iters }
    }
}

/// One reported row: a label, measured stats, and free-form metric columns.
#[derive(Clone, Debug)]
pub struct BenchRow {
    /// Row label (e.g. graph ID, K value).
    pub label: String,
    /// Wall-time stats (empty if the row only carries metrics).
    pub time: Stats,
    /// Extra named columns (speedup, error, GB/s, ...), in insertion order.
    pub metrics: Vec<(String, f64)>,
}

/// A named collection of rows, printed as one table.
pub struct BenchSuite {
    name: String,
    description: String,
    rows: Vec<BenchRow>,
    started: Instant,
}

impl BenchSuite {
    /// New suite; `name` should match the paper artifact (e.g. "fig9").
    pub fn new(name: &str, description: &str) -> Self {
        println!("\n=== {name}: {description} ===");
        Self {
            name: name.to_string(),
            description: description.to_string(),
            rows: Vec::new(),
            started: Instant::now(),
        }
    }

    /// Time `f` with warmup and record a row. Returns mean seconds.
    pub fn bench<T>(&mut self, label: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> f64 {
        for _ in 0..cfg.warmup {
            std::hint::black_box(f());
        }
        let mut stats = Stats::new();
        for _ in 0..cfg.iters.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            stats.push(t0.elapsed().as_secs_f64());
        }
        let mean = stats.mean();
        self.rows.push(BenchRow { label: label.to_string(), time: stats, metrics: Vec::new() });
        mean
    }

    /// Record a metrics-only row (for modelled quantities).
    pub fn report(&mut self, label: &str, metrics: &[(&str, f64)]) {
        self.rows.push(BenchRow {
            label: label.to_string(),
            time: Stats::new(),
            metrics: metrics.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// Attach metrics to the most recent row.
    pub fn annotate(&mut self, metrics: &[(&str, f64)]) {
        if let Some(row) = self.rows.last_mut() {
            row.metrics.extend(metrics.iter().map(|(k, v)| (k.to_string(), *v)));
        }
    }

    /// Print the table and optionally write JSON; returns the rows.
    pub fn finish(self) -> Vec<BenchRow> {
        // Collect the union of metric columns, preserving first-seen order.
        let mut cols: Vec<String> = Vec::new();
        for r in &self.rows {
            for (k, _) in &r.metrics {
                if !cols.iter().any(|c| c == k) {
                    cols.push(k.clone());
                }
            }
        }
        let has_time = self.rows.iter().any(|r| r.time.count() > 0);
        // Header.
        print!("{:<24}", "case");
        if has_time {
            print!(" {:>12} {:>12}", "time(mean)", "stddev");
        }
        for c in &cols {
            print!(" {c:>16}");
        }
        println!();
        for r in &self.rows {
            print!("{:<24}", r.label);
            if has_time {
                if r.time.count() > 0 {
                    print!(" {:>12} {:>12}", fmt_duration(r.time.mean()), fmt_duration(r.time.stddev()));
                } else {
                    print!(" {:>12} {:>12}", "-", "-");
                }
            }
            for c in &cols {
                match r.metrics.iter().find(|(k, _)| k == c) {
                    Some((_, v)) => print!(" {v:>16.6}"),
                    None => print!(" {:>16}", "-"),
                }
            }
            println!();
        }
        println!(
            "--- {} rows in {:.1}s ---",
            self.rows.len(),
            self.started.elapsed().as_secs_f64()
        );

        if let Ok(path) = std::env::var("TOPK_BENCH_JSON") {
            let rows_json: Vec<Json> = self
                .rows
                .iter()
                .map(|r| {
                    let mut o = Json::obj().set("label", r.label.as_str());
                    if r.time.count() > 0 {
                        o = o
                            .set("time_mean_s", r.time.mean())
                            .set("time_stddev_s", r.time.stddev())
                            .set("time_median_s", r.time.median());
                    }
                    for (k, v) in &r.metrics {
                        o = o.set(k, *v);
                    }
                    o
                })
                .collect();
            let doc = Json::obj()
                .set("suite", self.name.as_str())
                .set("description", self.description.as_str())
                .set("rows", Json::Arr(rows_json));
            // Append one JSON document per line (JSONL) so multiple suites
            // can share a report file.
            use std::io::Write;
            if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
                let _ = writeln!(f, "{}", doc.to_string());
            }
        }
        self.rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_rows_and_returns_mean() {
        let mut s = BenchSuite::new("test", "harness smoke");
        let mean = s.bench("sleepless", BenchConfig { warmup: 1, iters: 3 }, || {
            std::hint::black_box((0..10_000).sum::<usize>())
        });
        assert!(mean >= 0.0);
        s.report("modelled", &[("speedup", 6.22)]);
        s.annotate(&[("extra", 1.0)]);
        let rows = s.finish();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].time.count(), 3);
        assert_eq!(rows[1].metrics[0], ("speedup".to_string(), 6.22));
        assert_eq!(rows[1].metrics[1], ("extra".to_string(), 1.0));
    }

    #[test]
    fn json_report_is_written() {
        let dir = std::env::temp_dir().join("topk-bench-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("report.jsonl");
        let _ = std::fs::remove_file(&path);
        std::env::set_var("TOPK_BENCH_JSON", &path);
        let mut s = BenchSuite::new("jsontest", "json output");
        s.report("row", &[("x", 1.5)]);
        s.finish();
        std::env::remove_var("TOPK_BENCH_JSON");
        let content = std::fs::read_to_string(&path).unwrap();
        assert!(content.contains("\"suite\":\"jsontest\""), "{content}");
        assert!(content.contains("\"x\":1.5"));
    }
}

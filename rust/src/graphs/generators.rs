//! Synthetic graph generators.
//!
//! Each generator returns a symmetric weighted [`CooMatrix`] (undirected
//! graph adjacency) in canonical order. The four families cover the
//! topology classes of the paper's 13-graph suite (Table II):
//!
//! * [`rmat`] — Recursive-MATrix power-law graphs (web / social networks:
//!   wiki-Talk, web-Google, web-Berkstan, Flickr, patents, Wikipedia,
//!   wb-edu).
//! * [`mesh2d`] — jittered 2-D lattice meshes with low, near-constant
//!   degree (road networks: italy_osm, germany_osm, asia_osm,
//!   road_central; FEM meshes: venturiLevel3, hugetrace).
//! * [`erdos_renyi`] — uniform random baseline.
//! * [`scale_free_ba`] — Barabási-Albert preferential attachment.
//! * [`planted_partition`] — stochastic block model with known communities
//!   (ground truth for the spectral-clustering example).

use crate::fixed::Dataword;
use crate::sparse::{scale_value, CooMatrix, CsrMatrix, OocManifest, PacketFileWriter, PartitionPolicy};
use crate::util::rng::Pcg64;
use anyhow::Result;
use std::path::Path;

/// Deduplicate + symmetrize edge list into a canonical adjacency matrix.
fn finalize(n: usize, edges: Vec<(u32, u32)>, rng: &mut Pcg64, weighted: bool) -> CooMatrix {
    let mut m = CooMatrix::new(n, n);
    let mut seen = std::collections::HashSet::with_capacity(edges.len());
    for (u, v) in edges {
        if u == v {
            continue; // no self loops; the diagonal stays free for Laplacians
        }
        let key = if u < v { (u, v) } else { (v, u) };
        if !seen.insert(key) {
            continue;
        }
        let w = if weighted { 0.25 + 0.75 * rng.f32() } else { 1.0 };
        m.push(key.0 as usize, key.1 as usize, w);
        m.push(key.1 as usize, key.0 as usize, w);
    }
    m.canonicalize();
    m
}

/// R-MAT generator (Chakrabarti et al., SDM 2004).
///
/// `nnz_target` counts *directed* stored entries; the result is symmetrized
/// so the realized nnz is close to (slightly below, after dedup) the target.
/// Defaults matching Graph500: `a=0.57, b=0.19, c=0.19`.
pub fn rmat(n: usize, nnz_target: usize, a: f64, b: f64, c: f64, seed: u64) -> CooMatrix {
    assert!(n.is_power_of_two(), "rmat needs a power-of-two vertex count, got {n}");
    assert!(a + b + c < 1.0 + 1e-9, "probabilities must sum below 1");
    let mut rng = Pcg64::new(seed);
    let levels = n.trailing_zeros();
    let edge_goal = nnz_target / 2; // undirected edges
    let mut edges = Vec::with_capacity(edge_goal);
    for _ in 0..edge_goal {
        let (mut u, mut v) = (0u32, 0u32);
        for _ in 0..levels {
            u <<= 1;
            v <<= 1;
            let r = rng.f64();
            if r < a {
                // top-left quadrant
            } else if r < a + b {
                v |= 1;
            } else if r < a + b + c {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        edges.push((u, v));
    }
    finalize(n, edges, &mut rng, true)
}

/// Deterministic symmetric edge weight in `[0.25, 1.0)`: a splitmix64
/// finalizer over `(seed, min(u,v), max(u,v))`. Unlike [`rmat`]'s
/// order-dependent weight draws, this lets the streaming scaler revisit the
/// edge stream shard by shard and agree on every weight.
fn edge_weight(seed: u64, u: u32, v: u32) -> f32 {
    let (a, b) = if u < v { (u, v) } else { (v, u) };
    let mut z = seed ^ (((a as u64) << 32) | b as u64);
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    0.25 + 0.75 * ((z >> 40) as f32 / (1u64 << 24) as f32)
}

/// Replay the R-MAT endpoint stream: `edge_goal` recursive quadrant
/// descents from one `Pcg64` run. The stream is a pure function of the
/// arguments, so per-shard passes regenerate identical endpoints.
fn rmat_endpoints(n: usize, edge_goal: usize, a: f64, b: f64, c: f64, seed: u64, mut sink: impl FnMut(u32, u32)) {
    let mut rng = Pcg64::new(seed);
    let levels = n.trailing_zeros();
    for _ in 0..edge_goal {
        let (mut u, mut v) = (0u32, 0u32);
        for _ in 0..levels {
            u <<= 1;
            v <<= 1;
            let r = rng.f64();
            if r < a {
            } else if r < a + b {
                v |= 1;
            } else if r < a + b + c {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        sink(u, v);
    }
}

/// One shard's symmetrized, deduplicated entries `(row, col)` with
/// `row in [row_start, row_end)`, sorted in CSR order. Shards deduplicate
/// independently but agree globally: both orientations of an undirected
/// edge survive or vanish together.
fn rmat_shard_entries(
    n: usize,
    edge_goal: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
    row_start: usize,
    row_end: usize,
) -> Vec<(u32, u32)> {
    let mut entries = Vec::new();
    rmat_endpoints(n, edge_goal, a, b, c, seed, |u, v| {
        if u == v {
            return; // no self loops, matching `finalize`
        }
        if (row_start..row_end).contains(&(u as usize)) {
            entries.push((u, v));
        }
        if (row_start..row_end).contains(&(v as usize)) {
            entries.push((v, u));
        }
    });
    entries.sort_unstable();
    entries.dedup();
    entries
}

/// Streaming R-MAT scaler: generate a power-law graph **directly into an
/// OOC packet directory**, never materializing the whole matrix. This is
/// how n ≥ 2^22 inputs for the out-of-core datapath are produced on hosts
/// whose RAM the graph exceeds.
///
/// Peak residency is one shard's entries (~nnz/cus) plus an O(n) indptr
/// scratch. Two passes per shard over the deterministic endpoint stream:
/// pass A accumulates the global Frobenius norm over the deduplicated
/// entries, pass B quantizes with `V::from_f32(scale_value(w, 1/fro))` —
/// the exact composition the resident prepare applies — and writes the
/// shard's chunk file. Rows are split into `cus` equal ranges
/// ([`PartitionPolicy::EqualRows`]: a streaming producer has no global CSR
/// to nnz-balance over).
pub fn rmat_packets<V: Dataword>(
    dir: impl AsRef<Path>,
    n: usize,
    nnz_target: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
    cus: usize,
    chunk_target_bytes: Option<usize>,
) -> Result<OocManifest> {
    assert!(n.is_power_of_two(), "rmat needs a power-of-two vertex count, got {n}");
    assert!(a + b + c < 1.0 + 1e-9, "probabilities must sum below 1");
    assert!(cus >= 1, "need at least one CU shard");
    let edge_goal = nnz_target / 2;
    let rows: Vec<(usize, usize)> = (0..cus).map(|s| (s * n / cus, (s + 1) * n / cus)).collect();
    // Pass A: global Frobenius norm, one shard resident at a time. Each
    // stored entry lands in exactly one shard, so the shard-major f64 sum
    // covers every entry once.
    let mut sumsq = 0f64;
    for &(r0, r1) in &rows {
        for &(u, v) in &rmat_shard_entries(n, edge_goal, a, b, c, seed, r0, r1) {
            let w = edge_weight(seed, u, v) as f64;
            sumsq += w * w;
        }
    }
    let fro = if sumsq == 0.0 { 1.0 } else { sumsq.sqrt() };
    let inv = 1.0 / fro;
    // Pass B: re-collect each shard, quantize, write its chunk file.
    let mut writer = PacketFileWriter::new(dir.as_ref());
    if let Some(bytes) = chunk_target_bytes {
        writer = writer.chunk_target_bytes(bytes);
    }
    writer.write_shards::<V>(n, n, fro, PartitionPolicy::EqualRows, &rows, |_s, r0, r1| {
        let entries = rmat_shard_entries(n, edge_goal, a, b, c, seed, r0, r1);
        let mut indptr = vec![0usize; n + 1];
        for &(r, _) in &entries {
            indptr[r as usize + 1] += 1;
        }
        for i in 0..n {
            indptr[i + 1] += indptr[i];
        }
        let indices: Vec<u32> = entries.iter().map(|&(_, c)| c).collect();
        let vals: Vec<V> = entries
            .iter()
            .map(|&(u, v)| V::from_f32(scale_value(edge_weight(seed, u, v), inv)))
            .collect();
        Ok(CsrMatrix { nrows: n, ncols: n, indptr, indices, vals })
    })
}

/// Erdős–Rényi G(n, m): `nnz_target/2` uniform random edges.
pub fn erdos_renyi(n: usize, nnz_target: usize, seed: u64) -> CooMatrix {
    let mut rng = Pcg64::new(seed);
    let m = nnz_target / 2;
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        edges.push((u, v));
    }
    finalize(n, edges, &mut rng, true)
}

/// Jittered 2-D lattice: `rows x cols` grid with 4-neighbour links, each
/// kept with probability `keep`, plus sparse random "shortcut" edges
/// (fraction `shortcuts` of the lattice edges). Mimics road-network
/// topology: huge diameter, degree ~2-4, near-banded structure.
pub fn mesh2d(rows: usize, cols: usize, keep: f64, shortcuts: f64, seed: u64) -> CooMatrix {
    let n = rows * cols;
    let mut rng = Pcg64::new(seed);
    let mut edges = Vec::with_capacity(2 * n);
    let at = |r: usize, c: usize| (r * cols + c) as u32;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols && rng.chance(keep) {
                edges.push((at(r, c), at(r, c + 1)));
            }
            if r + 1 < rows && rng.chance(keep) {
                edges.push((at(r, c), at(r + 1, c)));
            }
        }
    }
    let extra = (edges.len() as f64 * shortcuts) as usize;
    for _ in 0..extra {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        edges.push((u, v));
    }
    finalize(n, edges, &mut rng, true)
}

/// Barabási–Albert preferential attachment with `m_links` edges per new
/// vertex. Produces a heavy-tailed degree distribution by construction.
pub fn scale_free_ba(n: usize, m_links: usize, seed: u64) -> CooMatrix {
    assert!(n > m_links && m_links >= 1);
    let mut rng = Pcg64::new(seed);
    // Target list with repetition proportional to degree.
    let mut targets: Vec<u32> = (0..m_links as u32).collect();
    let mut edges = Vec::with_capacity(n * m_links);
    for v in m_links..n {
        let mut chosen = std::collections::HashSet::new();
        while chosen.len() < m_links {
            let t = targets[rng.range(0, targets.len())];
            chosen.insert(t);
        }
        // Deterministic iteration order (HashSet order varies per process,
        // which would make the generator non-reproducible across runs).
        let mut chosen: Vec<u32> = chosen.into_iter().collect();
        chosen.sort_unstable();
        for &t in &chosen {
            edges.push((v as u32, t));
            targets.push(t);
            targets.push(v as u32);
        }
    }
    finalize(n, edges, &mut rng, true)
}

/// Stochastic block model: `k` equal communities over `n` vertices, edge
/// probability `p_in` inside a community and `p_out` across. Returns the
/// adjacency and the ground-truth community label per vertex.
pub fn planted_partition(n: usize, k: usize, p_in: f64, p_out: f64, seed: u64) -> (CooMatrix, Vec<usize>) {
    assert!(k >= 1 && n >= k);
    let mut rng = Pcg64::new(seed);
    let labels: Vec<usize> = (0..n).map(|i| i * k / n).collect();
    // Sample expected number of edges per block pair instead of testing all
    // O(n^2) pairs: for each pair class draw Binomial(pairs, p) ~ Poisson.
    let mut edges = Vec::new();
    let approx_edges_in = (p_in * (n * n) as f64 / (2.0 * k as f64)) as usize;
    let approx_edges_out = (p_out * (n * n) as f64 * (k - 1) as f64 / (2.0 * k as f64)) as usize;
    for _ in 0..approx_edges_in {
        let c = rng.range(0, k);
        let lo = c * n / k;
        let hi = (c + 1) * n / k;
        edges.push((rng.range(lo, hi) as u32, rng.range(lo, hi) as u32));
    }
    for _ in 0..approx_edges_out {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        if labels[u as usize] != labels[v as usize] {
            edges.push((u, v));
        }
    }
    (finalize(n, edges, &mut rng, false), labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmat_shape_and_symmetry() {
        let m = rmat(1 << 8, 4 * (1 << 8), 0.57, 0.19, 0.19, 1);
        assert_eq!(m.nrows, 256);
        assert!(m.is_symmetric(0.0));
        // Dedup loses some edges; expect at least half the target.
        assert!(m.nnz() > 2 * (1 << 8), "nnz={}", m.nnz());
        assert!(m.nnz() <= 4 * (1 << 8));
    }

    #[test]
    fn rmat_is_deterministic_per_seed() {
        let a = rmat(1 << 7, 1 << 9, 0.57, 0.19, 0.19, 9);
        let b = rmat(1 << 7, 1 << 9, 0.57, 0.19, 0.19, 9);
        let c = rmat(1 << 7, 1 << 9, 0.57, 0.19, 0.19, 10);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn rmat_degree_skew_exceeds_er() {
        let n = 1 << 10;
        let r = rmat(n, 8 * n, 0.65, 0.15, 0.15, 4);
        let e = erdos_renyi(n, 8 * n, 4);
        let max_deg = |m: &CooMatrix| {
            let mut d = vec![0usize; m.nrows];
            for &r in &m.rows {
                d[r as usize] += 1;
            }
            *d.iter().max().unwrap()
        };
        assert!(max_deg(&r) > 2 * max_deg(&e), "rmat={} er={}", max_deg(&r), max_deg(&e));
    }

    #[test]
    fn mesh_degree_is_bounded() {
        let m = mesh2d(32, 32, 0.95, 0.01, 3);
        let mut deg = vec![0usize; m.nrows];
        for &r in &m.rows {
            deg[r as usize] += 1;
        }
        let max = *deg.iter().max().unwrap();
        assert!(max <= 10, "road-like mesh should have tiny max degree, got {max}");
        assert!(m.is_symmetric(0.0));
    }

    #[test]
    fn ba_has_no_self_loops_or_duplicates() {
        let m = scale_free_ba(500, 3, 5);
        let mut seen = std::collections::HashSet::new();
        for i in 0..m.nnz() {
            assert_ne!(m.rows[i], m.cols[i], "self loop");
            assert!(seen.insert((m.rows[i], m.cols[i])), "duplicate entry");
        }
    }

    #[test]
    fn planted_partition_is_assortative() {
        let (m, labels) = planted_partition(400, 4, 0.1, 0.005, 6);
        let (mut within, mut across) = (0usize, 0usize);
        for i in 0..m.nnz() {
            if labels[m.rows[i] as usize] == labels[m.cols[i] as usize] {
                within += 1;
            } else {
                across += 1;
            }
        }
        assert!(within > 3 * across, "within={within} across={across}");
    }

    #[test]
    fn rmat_packets_is_symmetric_deterministic_and_shard_count_invariant() {
        use crate::sparse::OocMatrix;
        let (n, target) = (1 << 9, 8 << 9);
        let dir_a = crate::sparse::ooc::scratch_dir("gen-a");
        let dir_b = crate::sparse::ooc::scratch_dir("gen-b");
        let dir_c = crate::sparse::ooc::scratch_dir("gen-c");
        let ma = rmat_packets::<f32>(&dir_a, n, target, 0.57, 0.19, 0.19, 11, 3, Some(4096)).unwrap();
        let mb = rmat_packets::<f32>(&dir_b, n, target, 0.57, 0.19, 0.19, 11, 3, Some(4096)).unwrap();
        // Different shard count: same graph, different file geometry.
        let mc = rmat_packets::<f32>(&dir_c, n, target, 0.57, 0.19, 0.19, 11, 5, Some(4096)).unwrap();
        assert_eq!(ma.nnz, mb.nnz);
        assert_eq!(ma.fro.to_bits(), mb.fro.to_bits(), "fro is deterministic");
        assert_eq!(ma.nnz, mc.nnz, "dedup must not depend on shard boundaries");
        assert_eq!(ma.fro.to_bits(), mc.fro.to_bits());
        assert!(ma.nnz > target / 3, "dedup keeps most of the target, got {}", ma.nnz);

        let read = |dir: &std::path::Path| {
            let m = OocMatrix::<f32>::open(dir).unwrap();
            let mut entries = Vec::new();
            m.for_each_entry(|r, c, v| entries.push((r, c, v.to_bits())));
            entries
        };
        let ea = read(&dir_a);
        assert_eq!(ea, read(&dir_b), "same seed, same bytes");
        assert_eq!(ea.len(), ma.nnz);
        let mut ec = read(&dir_c);
        ec.sort_unstable();
        let mut ea_sorted = ea.clone();
        ea_sorted.sort_unstable();
        assert_eq!(ea_sorted, ec, "5-shard layout stores the same entry set as 3-shard");
        // Symmetric, no self loops, values in the open normalized interval.
        let set: std::collections::HashSet<_> = ea.iter().copied().collect();
        for &(r, c, bits) in &ea {
            assert_ne!(r, c, "self loop");
            assert!(set.contains(&(c, r, bits)), "missing transpose of ({r},{c})");
            let v = f32::from_bits(bits);
            assert!(v > 0.0 && v < 1.0, "normalized value out of range: {v}");
        }
        for d in [dir_a, dir_b, dir_c] {
            std::fs::remove_dir_all(d).ok();
        }
    }

    #[test]
    fn weights_are_in_unit_interval() {
        let m = rmat(1 << 6, 1 << 8, 0.57, 0.19, 0.19, 2);
        assert!(m.vals.iter().all(|&v| v > 0.0 && v <= 1.0));
    }
}

//! Adjacency → Laplacian transforms used by spectral methods (§I): the
//! application layer the paper motivates (spectral clustering consumes the
//! Top-K eigenvectors of a graph operator).

use crate::sparse::CooMatrix;

/// Which Laplacian-family operator to build.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum LaplacianKind {
    /// `L = D - A` (combinatorial Laplacian).
    Unnormalized,
    /// `L_sym = I - D^{-1/2} A D^{-1/2}` (symmetric normalized).
    SymmetricNormalized,
    /// `W = D^{-1/2} A D^{-1/2}` — the operator whose *largest* eigenpairs
    /// drive Ng-Jordan-Weiss spectral clustering; this is the natural
    /// input for a Top-K (largest) eigensolver like ours.
    NormalizedAdjacency,
}

/// Build the requested operator from a symmetric adjacency matrix.
/// Isolated vertices (degree 0) get a unit diagonal in the normalized
/// variants so the operator stays well-defined.
pub fn adjacency_to_laplacian(adj: &CooMatrix, kind: LaplacianKind) -> CooMatrix {
    assert_eq!(adj.nrows, adj.ncols, "adjacency must be square");
    let n = adj.nrows;
    // Weighted degrees.
    let mut deg = vec![0.0f64; n];
    for i in 0..adj.nnz() {
        deg[adj.rows[i] as usize] += adj.vals[i] as f64;
    }
    let inv_sqrt: Vec<f64> = deg.iter().map(|&d| if d > 0.0 { 1.0 / d.sqrt() } else { 0.0 }).collect();

    let mut out = CooMatrix::new(n, n);
    match kind {
        LaplacianKind::Unnormalized => {
            for i in 0..adj.nnz() {
                out.push(adj.rows[i] as usize, adj.cols[i] as usize, -adj.vals[i]);
            }
            for (i, &d) in deg.iter().enumerate() {
                if d != 0.0 {
                    out.push(i, i, d as f32);
                }
            }
        }
        LaplacianKind::SymmetricNormalized => {
            for i in 0..adj.nnz() {
                let (r, c) = (adj.rows[i] as usize, adj.cols[i] as usize);
                let v = -(adj.vals[i] as f64) * inv_sqrt[r] * inv_sqrt[c];
                out.push(r, c, v as f32);
            }
            for i in 0..n {
                out.push(i, i, 1.0);
            }
        }
        LaplacianKind::NormalizedAdjacency => {
            for i in 0..adj.nnz() {
                let (r, c) = (adj.rows[i] as usize, adj.cols[i] as usize);
                let v = (adj.vals[i] as f64) * inv_sqrt[r] * inv_sqrt[c];
                out.push(r, c, v as f32);
            }
            // Isolated vertices: identity block keeps the spectrum in [-1,1].
            for (i, &d) in deg.iter().enumerate() {
                if d == 0.0 {
                    out.push(i, i, 1.0);
                }
            }
        }
    }
    out.canonicalize();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Path graph 0-1-2.
    fn path3() -> CooMatrix {
        let mut a = CooMatrix::new(3, 3);
        a.push(0, 1, 1.0);
        a.push(1, 0, 1.0);
        a.push(1, 2, 1.0);
        a.push(2, 1, 1.0);
        a.canonicalize();
        a
    }

    #[test]
    fn unnormalized_laplacian_rows_sum_to_zero() {
        let l = adjacency_to_laplacian(&path3(), LaplacianKind::Unnormalized);
        let ones = vec![1.0f32; 3];
        let y = l.spmv_ref(&ones);
        assert!(y.iter().all(|&v| v.abs() < 1e-6), "{y:?}");
    }

    #[test]
    fn normalized_adjacency_has_unit_top_eigenvalue_direction() {
        // For W = D^{-1/2} A D^{-1/2}, the vector D^{1/2} 1 satisfies W x = x.
        let a = path3();
        let w = adjacency_to_laplacian(&a, LaplacianKind::NormalizedAdjacency);
        let x = [1.0f32, (2.0f32).sqrt(), 1.0]; // sqrt of degrees (1,2,1)
        let y = w.spmv_ref(&x);
        for i in 0..3 {
            assert!((y[i] - x[i]).abs() < 1e-6, "i={i} {y:?}");
        }
    }

    #[test]
    fn sym_normalized_is_i_minus_w() {
        let a = path3();
        let l = adjacency_to_laplacian(&a, LaplacianKind::SymmetricNormalized);
        let w = adjacency_to_laplacian(&a, LaplacianKind::NormalizedAdjacency);
        let x = [0.3f32, -0.7, 0.2];
        let lx = l.spmv_ref(&x);
        let wx = w.spmv_ref(&x);
        for i in 0..3 {
            assert!((lx[i] - (x[i] - wx[i])).abs() < 1e-6);
        }
    }

    #[test]
    fn all_kinds_symmetric() {
        let a = path3();
        for kind in [
            LaplacianKind::Unnormalized,
            LaplacianKind::SymmetricNormalized,
            LaplacianKind::NormalizedAdjacency,
        ] {
            assert!(adjacency_to_laplacian(&a, kind).is_symmetric(1e-6), "{kind:?}");
        }
    }

    #[test]
    fn isolated_vertex_handled() {
        let mut a = CooMatrix::new(3, 3);
        a.push(0, 1, 1.0);
        a.push(1, 0, 1.0); // vertex 2 isolated
        let w = adjacency_to_laplacian(&a, LaplacianKind::NormalizedAdjacency);
        let y = w.spmv_ref(&[0.0, 0.0, 1.0]);
        assert_eq!(y[2], 1.0, "isolated vertex keeps identity action");
    }
}

//! Graph workload substrate: synthetic generators matched to the paper's
//! evaluation suite (Table II) plus graph-analytics helpers (adjacency /
//! Laplacian construction) for the spectral-clustering example.

mod catalog;
mod generators;
mod spectral;

pub use catalog::{catalog, CatalogEntry, TopologyClass};
pub use generators::{erdos_renyi, mesh2d, planted_partition, rmat, rmat_packets, scale_free_ba};
pub use spectral::{adjacency_to_laplacian, LaplacianKind};

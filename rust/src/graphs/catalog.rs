//! Synthetic twins of the paper's evaluation suite (Table II).
//!
//! The paper evaluates on 13 SuiteSparse matrices. Those files are not
//! available offline, so each entry here records the published `rows`,
//! `nnz`, and a topology class, and can `generate()` a synthetic graph with
//! the same class and (scaled) size. Lanczos cost is Θ(K·nnz) + reorth
//! Θ(n·K²), so matching `n`, `nnz`, and the degree-distribution family
//! preserves both the arithmetic intensity and the numerical behaviour the
//! evaluation depends on (see DESIGN.md, substitution table).

use crate::graphs::generators;
use crate::sparse::CooMatrix;

/// Topology family used to pick a generator.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum TopologyClass {
    /// Power-law web/social graph → R-MAT.
    PowerLaw,
    /// Road network / planar-ish mesh → jittered 2-D lattice.
    Road,
    /// FEM / simulation mesh → denser jittered lattice.
    Mesh,
}

/// One row of Table II plus generation metadata.
#[derive(Clone, Debug)]
pub struct CatalogEntry {
    /// Short ID used in the paper's figures (e.g. "WB-TA").
    pub id: &'static str,
    /// SuiteSparse name.
    pub name: &'static str,
    /// Published row count.
    pub rows: usize,
    /// Published non-zero count.
    pub nnz: usize,
    /// Topology family.
    pub class: TopologyClass,
    /// Seed so every twin is reproducible.
    pub seed: u64,
}

impl CatalogEntry {
    /// Published sparsity (% of cells that are non-zero), as in Table II.
    pub fn sparsity_pct(&self) -> f64 {
        100.0 * self.nnz as f64 / (self.rows as f64 * self.rows as f64)
    }

    /// Published COO footprint in GB (3 x 4 bytes per nnz).
    pub fn size_gb(&self) -> f64 {
        self.nnz as f64 * 12.0 / 1e9
    }

    /// Generate the synthetic twin at `1/scale` of the published size
    /// (`scale = 1` reproduces the full published dimensions).
    ///
    /// The generated matrix is symmetric with unit-interval weights; rows
    /// are rounded to the generator's natural granularity (power of two for
    /// R-MAT, rectangle for meshes), keeping nnz/row faithful.
    pub fn generate(&self, scale: usize) -> CooMatrix {
        assert!(scale >= 1);
        let rows = (self.rows / scale).max(64);
        let nnz = (self.nnz / scale).max(256);
        match self.class {
            TopologyClass::PowerLaw => {
                let n = rows.next_power_of_two();
                // Graph500-ish skew: heavier 'a' for the web graphs.
                generators::rmat(n, nnz, 0.57, 0.19, 0.19, self.seed)
            }
            TopologyClass::Road => {
                // Degree ≈ 2·nnz/rows ∈ [2, 4] for road graphs; keep that by
                // tuning the lattice keep-probability.
                let side = (rows as f64).sqrt().ceil() as usize;
                let target_degree = nnz as f64 / rows as f64;
                let keep = (target_degree / 4.0).clamp(0.3, 1.0);
                generators::mesh2d(side, side, keep, 0.002, self.seed)
            }
            TopologyClass::Mesh => {
                let side = (rows as f64).sqrt().ceil() as usize;
                let target_degree = nnz as f64 / rows as f64;
                let keep = (target_degree / 4.0).clamp(0.5, 1.0);
                generators::mesh2d(side, side, keep, 0.01, self.seed)
            }
        }
    }
}

/// The 13-graph catalog, ordered by nnz as in Table II.
pub fn catalog() -> Vec<CatalogEntry> {
    use TopologyClass::*;
    vec![
        CatalogEntry { id: "WB-TA", name: "wiki-Talk", rows: 2_394_385, nnz: 5_021_410, class: PowerLaw, seed: 101 },
        CatalogEntry { id: "WB-GO", name: "web-Google", rows: 916_428, nnz: 5_105_039, class: PowerLaw, seed: 102 },
        CatalogEntry { id: "WB-BE", name: "web-Berkstan", rows: 685_230, nnz: 7_600_595, class: PowerLaw, seed: 103 },
        CatalogEntry { id: "FL", name: "Flickr", rows: 820_878, nnz: 9_837_214, class: PowerLaw, seed: 104 },
        CatalogEntry { id: "IT", name: "italy_osm", rows: 6_686_493, nnz: 14_027_956, class: Road, seed: 105 },
        CatalogEntry { id: "PA", name: "patents", rows: 3_774_768, nnz: 14_970_767, class: PowerLaw, seed: 106 },
        CatalogEntry { id: "VL3", name: "venturiLevel3", rows: 4_026_819, nnz: 16_108_474, class: Mesh, seed: 107 },
        CatalogEntry { id: "DE", name: "germany_osm", rows: 11_548_845, nnz: 24_738_362, class: Road, seed: 108 },
        CatalogEntry { id: "ASIA", name: "asia_osm", rows: 11_950_757, nnz: 25_423_206, class: Road, seed: 109 },
        CatalogEntry { id: "RC", name: "road_central", rows: 14_081_816, nnz: 33_866_826, class: Road, seed: 110 },
        CatalogEntry { id: "WK", name: "Wikipedia", rows: 3_566_907, nnz: 45_030_389, class: PowerLaw, seed: 111 },
        CatalogEntry { id: "HT", name: "hugetrace-00020", rows: 16_002_413, nnz: 47_997_626, class: Mesh, seed: 112 },
        CatalogEntry { id: "WB", name: "wb-edu", rows: 9_845_725, nnz: 57_156_537, class: PowerLaw, seed: 113 },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thirteen_entries_sorted_by_nnz() {
        let c = catalog();
        assert_eq!(c.len(), 13);
        for w in c.windows(2) {
            assert!(w[0].nnz <= w[1].nnz, "{} > {}", w[0].name, w[1].name);
        }
    }

    #[test]
    fn sparsity_matches_published_order_of_magnitude() {
        // web-Google: 6.17e-4 % in Table II. (wiki-Talk's published
        // sparsity is internally inconsistent with its rows/nnz by 10x —
        // see DESIGN.md — so the check anchors on WB-GO and WB.)
        let go = catalog().into_iter().find(|e| e.id == "WB-GO").unwrap();
        let s = go.sparsity_pct();
        assert!((s - 6.17e-4).abs() / 6.17e-4 < 0.05, "sparsity {s}");
        // wb-edu: 5.90e-5 %.
        let wb = &catalog()[12];
        assert!((wb.sparsity_pct() - 5.90e-5).abs() / 5.90e-5 < 0.05);
    }

    #[test]
    fn size_gb_matches_table() {
        // Table II sizes track 12 bytes/nnz within ~12% (the published
        // column appears to include per-file metadata overhead).
        for (id, published) in [("WB-TA", 0.06), ("WK", 0.60), ("WB", 0.73)] {
            let e = catalog().into_iter().find(|e| e.id == id).unwrap();
            let rel = (e.size_gb() - published).abs() / published;
            assert!(rel < 0.12, "{id}: {} vs {published}", e.size_gb());
        }
    }

    #[test]
    fn generated_twin_tracks_scaled_size() {
        for id in ["WB-GO", "IT"] {
            let e = catalog().into_iter().find(|e| e.id == id).unwrap();
            let scale = 256;
            let m = e.generate(scale);
            let target_nnz = e.nnz / scale;
            assert!(
                m.nnz() > target_nnz / 4 && m.nnz() < target_nnz * 4,
                "{id}: nnz {} vs target {target_nnz}",
                m.nnz()
            );
            assert!(m.is_symmetric(0.0), "{id} twin must be symmetric");
        }
    }

    #[test]
    fn road_twin_has_low_degree_powerlaw_high() {
        let cat = catalog();
        let road = cat.iter().find(|e| e.id == "ASIA").unwrap().generate(1024);
        let web = cat.iter().find(|e| e.id == "WB-TA").unwrap().generate(1024);
        let max_deg = |m: &CooMatrix| {
            let mut d = vec![0usize; m.nrows];
            for &r in &m.rows {
                d[r as usize] += 1;
            }
            *d.iter().max().unwrap()
        };
        assert!(max_deg(&road) < 12);
        assert!(max_deg(&web) > 20);
    }
}

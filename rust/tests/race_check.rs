//! Detector-on integration tests (`cargo test --features race-check`):
//! prove the scoped-claim race detector actually fires on a deliberate
//! overlap (naming both call sites), and that a panicking task does not
//! leak its claimed ranges — the whole suite runs with the feature on in
//! CI, so these are the tests that keep the detector honest.

#![cfg(feature = "race-check")]

use std::sync::Mutex;
use topk_eigen::util::pool::ThreadPool;
use topk_eigen::util::ptr::SendPtr;
use topk_eigen::util::race;

/// The detector's scope registry is process-global and these tests assert
/// `active_scopes() == 0`, so they must not interleave.
static SERIAL: Mutex<()> = Mutex::new(());

fn payload_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        String::from("<non-string panic payload>")
    }
}

#[test]
fn overlapping_claims_panic_with_both_sites() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let pool = ThreadPool::new(2);
    let mut buf = vec![0.0f32; 64];
    let ptr = SendPtr(buf.as_mut_ptr());
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.scope_chunks(2, |task| {
            if task == 0 {
                // SAFETY: deliberately *not* disjoint — [0, 40) overlaps
                // task 1's [24, 64) — so the detector must refuse one of
                // the two claims before any aliasing `&mut` exists.
                let view = unsafe { ptr.slice_mut(0, 40) };
                view[0] = 1.0;
            } else {
                // SAFETY: as above — the deliberate overlap under test.
                let view = unsafe { ptr.slice_mut(24, 40) };
                view[0] = 2.0;
            }
        });
    }));
    let payload = r.expect_err("overlapping claims must panic through the fork/join");
    let msg = payload_message(payload.as_ref());
    assert!(msg.contains("race-check: overlapping claims"), "unexpected panic: {msg}");
    // Both the refused claim's site and the prior claim's site are named,
    // each as a `race_check.rs:<line>` location in this file.
    assert_eq!(msg.matches("race_check.rs").count(), 2, "both call sites named: {msg}");
    // The join completed despite the panic: the scope must be retired.
    assert_eq!(race::active_scopes(), 0, "scope leaked after overlap panic");
    // The pool survives and a disjoint claim set runs clean.
    pool.scope_chunks(2, |task| {
        // SAFETY: [0, 32) and [32, 64) tile the buffer disjointly and the
        // join precedes any other use.
        let view = unsafe { ptr.slice_mut(task * 32, 32) };
        view.fill(task as f32);
    });
    assert_eq!(buf[0], 0.0);
    assert_eq!(buf[63], 1.0);
}

#[test]
fn panicking_task_does_not_leak_claims() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let pool = ThreadPool::new(3);
    let mut buf = vec![0u64; 32];
    let ptr = SendPtr(buf.as_mut_ptr());
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.scope_chunks(4, |task| {
            // SAFETY: stripes of 8 tile [0, 32) disjointly per task; the
            // join precedes any other use of `buf`.
            let stripe = unsafe { ptr.slice_mut(task * 8, 8) };
            stripe.fill(task as u64 + 1);
            if task == 2 {
                panic!("task boom");
            }
        });
    }));
    // The task's own panic — not a detector report — reaches the publisher.
    let payload = r.expect_err("task panic must propagate");
    assert_eq!(payload_message(payload.as_ref()), "task boom");
    assert_eq!(race::active_scopes(), 0, "scope leaked after task panic");
    // The panicked scope's claims are gone: the *identical* ranges claim
    // cleanly in a fresh scope (a leak would report them as overlaps).
    pool.scope_chunks(4, |task| {
        // SAFETY: same disjoint stripes as above.
        let stripe = unsafe { ptr.slice_mut(task * 8, 8) };
        stripe.fill(10 + task as u64);
    });
    assert_eq!(buf, (0..32).map(|i| 10 + i as u64 / 8).collect::<Vec<_>>());
    // ...and the detector is still armed: a real overlap still fires.
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.scope_chunks(2, |_task| {
            // SAFETY: deliberately overlapping — every task claims the
            // whole buffer; the detector must refuse the second claim.
            let view = unsafe { ptr.slice_mut(0, 32) };
            view[0] = 99;
        });
    }));
    let msg = payload_message(r.expect_err("full-buffer overlap must panic").as_ref());
    assert!(msg.contains("race-check: overlapping claims"), "{msg}");
    assert_eq!(race::active_scopes(), 0);
}

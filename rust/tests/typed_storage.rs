//! Property tests for the typed mixed-precision storage datapath:
//! quantized-storage SpMV must track the f32 reference within an error
//! bound scaled by `nnz_per_row * V::ulp()` across all four storage
//! formats and shard counts {1, 3, 5, 8}, including the empty-tail-shard
//! and final-short-packet edge cases, and the 16-bit format must
//! *measurably* shrink the datapath (half the value bytes, 6 entries per
//! 512-bit line vs 5 at f32 — the §IV-B1 capacity table).

use std::sync::Arc;
use topk_eigen::fixed::{packet_capacity, Dataword, Precision, Q1_15, Q1_31, Q2_30};
use topk_eigen::lanczos::Operator;
use topk_eigen::prop_assert;
use topk_eigen::sparse::{CooMatrix, PacketStream, PartitionPolicy, ShardedSpmv};
use topk_eigen::util::pool::ThreadPool;
use topk_eigen::util::prop::{forall, Gen};

const SHARD_COUNTS: [usize; 4] = [1, 3, 5, 8];
const POLICIES: [PartitionPolicy; 2] = [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz];

/// Random symmetric COO matrix with entries in (-0.5, 0.5) — the
/// post-Frobenius-normalization value regime every storage format can
/// represent.
fn gen_sym_coo(g: &mut Gen) -> CooMatrix {
    let n = g.usize_in(4, 160).max(4);
    let edges = g.usize_in(n, 5 * n).max(4);
    let mut m = CooMatrix::new(n, n);
    for _ in 0..edges {
        let r = g.rng().range(0, n);
        let c = g.rng().range(0, n);
        let v = g.f64_in(-0.5, 0.5) as f32;
        m.push(r, c, v);
        if r != c {
            m.push(c, r, v);
        }
    }
    m.canonicalize();
    // Duplicate cells were summed by canonicalize() and can exceed 1 in
    // magnitude, where Q1.31/Q1.15 saturate and the ulp-scaled bounds no
    // longer apply; clamp back into the representable regime (the f32
    // reference and the typed copies both derive from the clamped matrix,
    // so the property itself is unaffected).
    for v in &mut m.vals {
        *v = v.clamp(-0.9, 0.9);
    }
    m
}

/// Sharded SpMV in storage format `V` vs the f32 serial reference, across
/// all shard counts and policies. The bound scales with the densest row:
/// each stored value is off by at most `ulp/2`, `|x| <= 1`, so a row of
/// `d` entries accumulates at most `d * ulp/2` quantization error (plus
/// f32 round-off slack).
fn check_format<V: Dataword>(g: &mut Gen, coo: &CooMatrix, x: &[f32], pool: &Arc<ThreadPool>) -> bool {
    let f32_csr = coo.to_csr();
    let reference = f32_csr.spmv(x);
    let typed = Arc::new(f32_csr.to_precision::<V>());
    prop_assert!(
        g,
        typed.value_bytes() == coo.nnz() * V::bytes(),
        "{}: value bytes {} != nnz {} * {}",
        V::NAME,
        typed.value_bytes(),
        coo.nnz(),
        V::bytes()
    );
    let bound = f32_csr.max_row_nnz().max(1) as f64 * V::ulp() + 1e-5;
    for shards in SHARD_COUNTS {
        for policy in POLICIES {
            let op = ShardedSpmv::new(Arc::clone(&typed), shards, policy, Arc::clone(pool));
            prop_assert!(g, op.cus() == shards, "{}: shard count", V::NAME);
            let mut y = vec![0.0f32; coo.nrows];
            op.apply(x, &mut y);
            for i in 0..y.len() {
                prop_assert!(
                    g,
                    ((y[i] - reference[i]).abs() as f64) <= bound,
                    "{}: row {i} off by {} > bound {bound} (shards={shards} policy={policy:?})",
                    V::NAME,
                    (y[i] - reference[i]).abs()
                );
            }
        }
    }
    true
}

#[test]
fn prop_quantized_spmv_tracks_f32_across_formats_and_shards() {
    forall("typed sharded SpMV within nnz_per_row * ulp of f32 for all formats", |g| {
        let coo = gen_sym_coo(g);
        let x = g.vec_f32(coo.ncols, -1.0, 1.0);
        let pool = Arc::new(ThreadPool::new(5));
        check_format::<f32>(g, &coo, &x, &pool)
            && check_format::<Q1_31>(g, &coo, &x, &pool)
            && check_format::<Q2_30>(g, &coo, &x, &pool)
            && check_format::<Q1_15>(g, &coo, &x, &pool)
    });
}

#[test]
fn prop_typed_empty_tail_shards_are_harmless() {
    // Fewer rows than shards: the partitioner pads with empty tail ranges,
    // which must neither panic nor perturb the output in any format.
    forall("typed sharded SpMV with more shards than rows", |g| {
        let n = g.usize_in(1, 7).max(1);
        let mut coo = CooMatrix::new(n, n);
        for r in 0..n {
            coo.push(r, r, g.f64_in(-0.5, 0.5) as f32);
            let c = g.rng().range(0, n);
            if c != r {
                let w = g.f64_in(-0.5, 0.5) as f32;
                coo.push(r, c, w);
                coo.push(c, r, w);
            }
        }
        coo.canonicalize();
        // Same saturation guard as gen_sym_coo: summed duplicates must stay
        // inside the fixed formats' representable range.
        for v in &mut coo.vals {
            *v = v.clamp(-0.9, 0.9);
        }
        let x = g.vec_f32(n, -1.0, 1.0);
        let pool = Arc::new(ThreadPool::new(4));
        check_format::<f32>(g, &coo, &x, &pool)
            && check_format::<Q1_31>(g, &coo, &x, &pool)
            && check_format::<Q2_30>(g, &coo, &x, &pool)
            && check_format::<Q1_15>(g, &coo, &x, &pool)
    });
}

#[test]
fn prop_typed_packet_stream_round_trips_with_short_tail() {
    // The final packet of a typed stream carries `nnz % capacity` entries
    // (when non-zero); every entry must round-trip within one ulp.
    forall("typed packet stream yields every entry once, short tail included", |g| {
        let coo = gen_sym_coo(g);
        let q: CooMatrix<Q1_15> = coo.to_precision::<Q1_15>();
        let cap = packet_capacity(16);
        prop_assert!(g, cap == 6, "capacity {cap}");
        let packets: Vec<_> = PacketStream::new(&q).collect();
        let expect_tail = coo.nnz() % cap;
        if expect_tail != 0 {
            prop_assert!(
                g,
                packets.last().map(|p| p.len) == Some(expect_tail),
                "tail len {:?} != {expect_tail}",
                packets.last().map(|p| p.len)
            );
        }
        let flat: Vec<(u32, u32, f32)> =
            packets.iter().flat_map(|p| p.entries().collect::<Vec<_>>()).collect();
        prop_assert!(g, flat.len() == coo.nnz(), "len {} vs {}", flat.len(), coo.nnz());
        for (i, &(r, c, v)) in flat.iter().enumerate() {
            prop_assert!(g, r == coo.rows[i] && c == coo.cols[i], "entry {i} index mismatch");
            prop_assert!(
                g,
                ((v - coo.vals[i]).abs() as f64) <= <Q1_15 as Dataword>::ulp(),
                "entry {i} value {} vs {}",
                v,
                coo.vals[i]
            );
        }
        true
    });
}

#[test]
fn q115_shrinks_the_datapath_measurably() {
    // The acceptance-bar numbers, asserted deterministically: 16-bit words
    // halve the value-array bytes, and a 512-bit line carries 6 entries
    // instead of 5, so a fixed matrix streams fewer packets.
    use topk_eigen::graphs;
    let mut coo = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 41);
    topk_eigen::sparse::normalize_frobenius(&mut coo);
    let f = Arc::new(coo.to_csr());
    let q = Arc::new(f.to_precision::<Q1_15>());
    assert_eq!(q.value_bytes() * 2, f.value_bytes());
    assert_eq!(packet_capacity(32), 5);
    assert_eq!(packet_capacity(16), 6);
    assert_eq!(Precision::FixedQ1_15.packet_capacity(), 6);
    for shards in SHARD_COUNTS {
        let a = ShardedSpmv::with_own_pool(Arc::clone(&f), shards, PartitionPolicy::BalancedNnz);
        let b = ShardedSpmv::with_own_pool(Arc::clone(&q), shards, PartitionPolicy::BalancedNnz);
        assert_eq!(a.packet_entries_per_line(), 5);
        assert_eq!(b.packet_entries_per_line(), 6);
        assert!(
            b.packets_per_apply() < a.packets_per_apply(),
            "shards={shards}: {} !< {}",
            b.packets_per_apply(),
            a.packets_per_apply()
        );
        assert!(b.bytes_per_apply() < a.bytes_per_apply(), "shards={shards}");
    }
}

#[test]
fn typed_solves_agree_with_f32_within_format_error() {
    // End-to-end: the coordinator's typed engines produce eigenvalues that
    // drift from the f32 datapath by at most a quantization-scale amount,
    // tighter for finer formats.
    use topk_eigen::coordinator::{SolveOptions, Solver};
    use topk_eigen::graphs;
    let m = graphs::mesh2d(16, 16, 0.9, 0.02, 11);
    let solve = |p: Precision| {
        let mut s = Solver::new(SolveOptions { k: 4, precision: p, ..Default::default() });
        s.solve(&m).unwrap()
    };
    let sf = solve(Precision::Float32);
    let s31 = solve(Precision::FixedQ1_31);
    let s15 = solve(Precision::FixedQ1_15);
    assert_eq!(sf.metrics.precision, "f32");
    assert_eq!(s31.metrics.precision, "q1.31");
    assert_eq!(s15.metrics.precision, "q1.15");
    let scale = sf.eigenvalues[0].abs().max(1e-12);
    let d31 = (s31.eigenvalues[0] - sf.eigenvalues[0]).abs() / scale;
    let d15 = (s15.eigenvalues[0] - sf.eigenvalues[0]).abs() / scale;
    assert!(d31 < 1e-4, "q1.31 drift {d31}");
    assert!(d15 < 5e-2, "q1.15 drift {d15}");
}

//! Property-based tests over the system's core invariants, using the
//! in-repo `util::prop` harness (proptest substitute; see DESIGN.md).

use topk_eigen::jacobi::{jacobi_eigen, JacobiMode};
use topk_eigen::lanczos::{lanczos, LanczosOptions, ReorthPolicy};
use topk_eigen::linalg::{self, Tridiagonal};
use topk_eigen::prop_assert;
use topk_eigen::sparse::{partition_rows_balanced, CooMatrix, PartitionPolicy, PacketStream};
use topk_eigen::util::prop::{forall, Gen};

/// Random symmetric COO matrix with entries in (-1, 1) (post-normalization
/// regime).
fn gen_sym_coo(g: &mut Gen) -> CooMatrix {
    let n = g.usize_in(4, 200).max(4);
    let edges = g.usize_in(n, 6 * n).max(4);
    let mut m = CooMatrix::new(n, n);
    for _ in 0..edges {
        let r = g.rng().range(0, n);
        let c = g.rng().range(0, n);
        let v = g.f64_in(-0.5, 0.5) as f32;
        m.push(r, c, v);
        if r != c {
            m.push(c, r, v);
        }
    }
    m.canonicalize();
    m
}

#[test]
fn prop_coo_csr_round_trip() {
    forall("COO -> CSR -> COO is identity on canonical matrices", |g| {
        let m = gen_sym_coo(g);
        let back = m.to_csr().to_coo();
        prop_assert!(g, back == m, "round trip changed the matrix (n={})", m.nrows);
        true
    });
}

#[test]
fn prop_csr_spmv_matches_coo_spmv() {
    forall("CSR and COO SpMV agree", |g| {
        let m = gen_sym_coo(g);
        let x = g.vec_f32(m.ncols, -1.0, 1.0);
        let a = m.spmv_ref(&x);
        let b = m.to_csr().spmv(&x);
        for i in 0..a.len() {
            prop_assert!(g, (a[i] - b[i]).abs() < 1e-4, "row {i}: {} vs {}", a[i], b[i]);
        }
        true
    });
}

#[test]
fn prop_partitions_tile_and_preserve_nnz() {
    forall("partitions tile [0,n) and conserve nnz", |g| {
        let m = gen_sym_coo(g).to_csr();
        let shards = g.usize_in(1, 9).max(1);
        let policy = *g.choose(&[PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz]);
        let parts = partition_rows_balanced(&m, shards, policy);
        prop_assert!(g, parts.len() == shards, "shard count");
        prop_assert!(g, parts[0].row_start == 0, "start");
        prop_assert!(g, parts.last().unwrap().row_end == m.nrows, "end");
        let mut nnz = 0;
        for w in parts.windows(2) {
            prop_assert!(g, w[0].row_end == w[1].row_start, "gap in tiling");
        }
        for p in &parts {
            nnz += p.nnz;
        }
        prop_assert!(g, nnz == m.nnz(), "nnz {} != {}", nnz, m.nnz());
        true
    });
}

#[test]
fn prop_packet_stream_round_trips() {
    forall("packet stream yields every entry exactly once", |g| {
        let m = gen_sym_coo(g);
        let flat: Vec<(u32, u32, f32)> =
            PacketStream::new(&m).flat_map(|p| p.entries().collect::<Vec<_>>()).collect();
        prop_assert!(g, flat.len() == m.nnz(), "len {} vs {}", flat.len(), m.nnz());
        for (i, &(r, c, v)) in flat.iter().enumerate() {
            prop_assert!(
                g,
                r == m.rows[i] && c == m.cols[i] && v == m.vals[i],
                "entry {i} mismatch"
            );
        }
        true
    });
}

#[test]
fn prop_lanczos_basis_orthonormal_under_full_reorth() {
    forall("Lanczos basis stays orthonormal with full reorth", |g| {
        let m = gen_sym_coo(g);
        let k = g.usize_in(2, 12.min(m.nrows)).max(2);
        let res = lanczos(
            &m.to_csr(),
            &LanczosOptions { k, reorth: ReorthPolicy::Every, ..Default::default() },
        );
        for i in 0..res.basis.len() {
            let n = linalg::norm2(&res.basis[i]);
            prop_assert!(g, (n - 1.0).abs() < 1e-4, "row {i} norm {n}");
            for j in 0..i {
                let d = linalg::dot(&res.basis[i], &res.basis[j]).abs();
                prop_assert!(g, d < 1e-3, "rows {i},{j} dot {d}");
            }
        }
        true
    });
}

#[test]
fn prop_lanczos_ritz_values_within_spectrum_bound() {
    forall("Ritz values bounded by Gershgorin of T and ||M||_F", |g| {
        let mut m = gen_sym_coo(g);
        topk_eigen::sparse::normalize_frobenius(&mut m);
        let k = g.usize_in(2, 10.min(m.nrows)).max(2);
        let res = lanczos(&m.to_csr(), &LanczosOptions { k, ..Default::default() });
        let eig = jacobi_eigen(&res.tridiag, JacobiMode::Cyclic, 1e-10);
        for &lam in &eig.eigenvalues {
            // After Frobenius normalization, |lambda| <= ||M||_2 <= 1.
            prop_assert!(g, lam.abs() <= 1.0 + 1e-5, "lambda {lam} escapes the unit bound");
        }
        true
    });
}

#[test]
fn prop_jacobi_preserves_trace_and_orthogonality() {
    forall("Jacobi similarity preserves trace; V orthonormal", |g| {
        let k = g.usize_in(2, 24).max(2);
        let alpha: Vec<f64> = (0..k).map(|_| g.f64_in(-1.0, 1.0)).collect();
        let beta: Vec<f64> = (0..k - 1).map(|_| g.f64_in(-1.0, 1.0)).collect();
        let t = Tridiagonal::new(alpha.clone(), beta);
        let mode = *g.choose(&[JacobiMode::Cyclic, JacobiMode::Systolic]);
        let eig = jacobi_eigen(&t, mode, 1e-9);
        let trace: f64 = alpha.iter().sum();
        let eigsum: f64 = eig.eigenvalues.iter().sum();
        prop_assert!(g, (trace - eigsum).abs() < 1e-5 * (1.0 + trace.abs()), "trace {trace} vs {eigsum} ({mode:?})");
        let defect = eig.eigenvectors.orthonormality_defect();
        prop_assert!(g, defect < 1e-5, "orthonormality defect {defect} ({mode:?})");
        true
    });
}

#[test]
fn prop_jacobi_eigenvalues_match_sturm_counts() {
    forall("each Jacobi eigenvalue is in T's spectrum (Sturm check)", |g| {
        let k = g.usize_in(2, 16).max(2);
        let t = Tridiagonal::new(
            (0..k).map(|_| g.f64_in(-1.0, 1.0)).collect(),
            (0..k - 1).map(|_| g.f64_in(-1.0, 1.0)).collect(),
        );
        let eig = jacobi_eigen(&t, JacobiMode::Systolic, 1e-10);
        for &lam in &eig.eigenvalues {
            let lo = t.eigenvalues_below(lam - 1e-6);
            let hi = t.eigenvalues_below(lam + 1e-6);
            prop_assert!(g, hi > lo, "lambda {lam} not found by Sturm count");
        }
        true
    });
}

#[test]
fn prop_fixed_point_quantization_bounded_by_ulp() {
    use topk_eigen::fixed::{Fixed, Precision, Q1_15, Q1_31, Q2_30};
    forall("quantization error <= ulp/2 inside the representable range", |g| {
        let x = g.f64_in(-0.999, 0.999);
        prop_assert!(g, (Q1_31::quantize(x) - x).abs() <= Q1_31::ulp(), "q1.31 at {x}");
        prop_assert!(g, (Q2_30::quantize(x) - x).abs() <= Q2_30::ulp(), "q2.30 at {x}");
        prop_assert!(g, (Q1_15::quantize(x) - x).abs() <= Q1_15::ulp(), "q1.15 at {x}");
        let xf = x as f32;
        for p in [Precision::FixedQ1_31, Precision::FixedQ2_30, Precision::FixedQ1_15] {
            let q = p.quantize(xf);
            prop_assert!(g, q.abs() <= 1.0001, "{p:?} escaped range: {q}");
        }
        true
    });
}

#[test]
fn prop_frobenius_normalization_bounds_entries() {
    forall("after normalization all entries are in [-1, 1]", |g| {
        let mut m = gen_sym_coo(g);
        // Inflate values to exercise the scaling.
        for v in &mut m.vals {
            *v *= 100.0;
        }
        let norm = topk_eigen::sparse::normalize_frobenius(&mut m);
        prop_assert!(g, norm >= 0.0, "negative norm");
        for &v in &m.vals {
            prop_assert!(g, v.abs() <= 1.0, "entry {v} escaped after normalization");
        }
        true
    });
}

#[test]
fn prop_solver_eigenvalues_sorted_and_bounded() {
    use topk_eigen::coordinator::{SolveOptions, Solver};
    forall("solver output is magnitude-sorted and Frobenius-bounded", |g| {
        let m = gen_sym_coo(g);
        if m.nnz() == 0 || m.nrows < 6 {
            return true;
        }
        let k = g.usize_in(1, 6.min(m.nrows)).max(1);
        let mut solver = Solver::new(SolveOptions { k, ..Default::default() });
        let sol = match solver.solve(&m) {
            Ok(s) => s,
            Err(e) => {
                g.fail(format!("solve failed: {e}"));
                return false;
            }
        };
        for w in sol.eigenvalues.windows(2) {
            prop_assert!(g, w[0].abs() >= w[1].abs() - 1e-9, "not sorted: {:?}", sol.eigenvalues);
        }
        for (lambda, v) in sol.pairs() {
            prop_assert!(g, lambda.abs() <= sol.frobenius_norm * 1.001, "|{lambda}| > fro");
            let n = linalg::norm2(v);
            prop_assert!(g, (n - 1.0).abs() < 1e-3, "eigenvector norm {n}");
        }
        true
    });
}

#[test]
fn prop_round_robin_period_is_k_minus_1() {
    // The circle method is cyclic with period k-1: after k-1 advances the
    // pairing returns to the initial adjacent pairing.
    forall("round robin period", |g| {
        let k = 2 * g.usize_in(1, 16).max(1);
        let mut rr = topk_eigen::jacobi::RoundRobin::new(k);
        let initial = rr.pairs();
        for _ in 0..k - 1 {
            rr.advance();
        }
        prop_assert!(g, rr.pairs() == initial, "period != k-1 for k={k}");
        true
    });
}

#[test]
fn prop_mmio_round_trip() {
    forall("MatrixMarket write/read round trip", |g| {
        let m = gen_sym_coo(g);
        let dir = std::env::temp_dir().join("topk-prop");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("rt-{}.mtx", g.rng().next_u64()));
        topk_eigen::sparse::write_matrix_market(&path, &m).unwrap();
        let back = topk_eigen::sparse::read_matrix_market(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        prop_assert!(g, back.nnz() == m.nnz(), "nnz changed");
        let x = g.vec_f32(m.ncols, -1.0, 1.0);
        let (a, b) = (m.spmv_ref(&x), back.spmv_ref(&x));
        for i in 0..a.len() {
            // f32 values survive the decimal round trip to ~1e-6 relative.
            prop_assert!(g, (a[i] - b[i]).abs() <= 1e-5 * (1.0 + a[i].abs()), "row {i}");
        }
        true
    });
}

#[test]
fn prop_lanczos_invariant_under_partitioning() {
    // The tridiagonal output must not depend on how SpMV is sharded.
    use std::sync::Arc;
    forall("lanczos is partition-invariant", |g| {
        let m = Arc::new(gen_sym_coo(g).to_csr());
        if m.nrows < 8 {
            return true;
        }
        let pool = Arc::new(topk_eigen::util::pool::ThreadPool::new(3));
        let cus = g.usize_in(2, 6).max(2);
        let sharded = topk_eigen::lanczos::ShardedSpmv::new(
            Arc::clone(&m),
            cus,
            PartitionPolicy::BalancedNnz,
            pool,
        );
        let opts = LanczosOptions { k: 6.min(m.nrows), ..Default::default() };
        let a = lanczos(m.as_ref(), &opts);
        let b = lanczos(&sharded, &opts);
        for i in 0..a.tridiag.k().min(b.tridiag.k()) {
            prop_assert!(
                g,
                (a.tridiag.alpha[i] - b.tridiag.alpha[i]).abs() < 1e-6,
                "alpha[{i}] differs across partitioning"
            );
        }
        true
    });
}

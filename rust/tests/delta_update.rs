//! Acceptance tests for the delta-update path (evolving graphs):
//!
//! * After a ~1%-dirty delta on an n=2^14 R-MAT matrix, the incremental
//!   re-prep rebuilds **only dirty shards** (per-shard rebuild telemetry),
//!   and solves against the refreshed engine are **exactly equal** to a
//!   from-scratch `register` + `prepared` of the mutated matrix — across
//!   all four storage precisions.
//! * A warm-kept re-solve (seed retained across the generation bump under
//!   the relative-perturbation guard) uses **fewer SpMV applications**
//!   than the same solve run cold, under adaptive stopping.

use topk_eigen::coordinator::{MatrixRegistry, RegistryConfig, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::graphs;
use topk_eigen::lanczos::LanczosWorkspace;
use topk_eigen::sparse::{CooDelta, CooMatrix};

const N: usize = 1 << 14;

fn acceptance_matrix() -> (CooMatrix, CooMatrix) {
    let base = graphs::rmat(N, 8 * N, 0.57, 0.19, 0.19, 4242);
    let mut canon = base.clone();
    canon.canonicalize();
    (base, canon)
}

/// Symmetric value-perturbation delta dirtying ~1% of the rows: edits are
/// confined to entries with **both** endpoints in the leading row band, so
/// the mirrored edits stay inside the band too and most CU shards see no
/// dirty row (localized churn, the common evolving-graph pattern).
fn one_percent_delta(canon: &CooMatrix) -> CooDelta {
    let band = N / 100;
    let mut d = CooDelta::new(canon.nrows, canon.ncols);
    for i in 0..canon.nnz() {
        let (r, c) = (canon.rows[i] as usize, canon.cols[i] as usize);
        if r <= c && c < band {
            d.upsert_sym(r, c, canon.vals[i] * 1.05 + 1e-5);
        }
    }
    assert!(!d.is_empty());
    d
}

#[test]
fn one_percent_delta_rebuilds_only_dirty_shards_and_matches_scratch_exactly() {
    let (base, canon) = acceptance_matrix();
    let delta = one_percent_delta(&canon);
    let mut mutated = canon.clone();
    {
        let mut d = delta.clone();
        d.canonicalize();
        let rep = mutated.apply_delta(&d);
        assert!(rep.changed > 0);
        assert!(rep.dirty_rows.len() * 100 <= 2 * N, "~1% of rows dirty, got {}", rep.dirty_rows.len());
    }

    for precision in
        [Precision::Float32, Precision::FixedQ1_31, Precision::FixedQ2_30, Precision::FixedQ1_15]
    {
        let opts = SolveOptions { k: 6, precision, ..Default::default() };

        // Incremental path: register, prepare, delta, refresh.
        let reg = MatrixRegistry::default();
        let h = reg.register(base.clone()).expect("register");
        let prep1 = reg.prepared(h, &opts).expect("initial prepare");
        assert_eq!(prep1.generation(), 1);
        let report = reg.update(h, delta.clone()).expect("update");
        assert_eq!(report.generation, 2);
        let prep2 = reg.prepared(h, &opts).expect("incremental refresh");
        assert_eq!(prep2.generation(), 2);

        // Telemetry: the refresh was incremental and rebuilt only the
        // shards holding dirty rows — the delta is confined to the leading
        // 1% of rows, which R-MAT skew keeps inside the first CU shard
        // (allow two in case a partition boundary bisects the band).
        let stats = reg.stats();
        assert_eq!(stats.incremental_rebuilds, 1, "{precision:?}: {stats:?}");
        assert_eq!(stats.full_rebuilds, 0, "{precision:?}: {stats:?}");
        assert_eq!(stats.shards_rebuilt + stats.shards_reused, opts.cus as u64, "{precision:?}: {stats:?}");
        assert!((1..=2).contains(&stats.shards_rebuilt), "only dirty shards rebuild: {stats:?}");
        assert!(stats.shards_reused >= opts.cus as u64 - 2, "clean shards carry over: {stats:?}");

        // From-scratch path on the mutated matrix.
        let reg2 = MatrixRegistry::default();
        let h2 = reg2.register(mutated.clone()).expect("register mutated");
        let fresh = reg2.prepared(h2, &opts).expect("fresh prepare");

        // Exact equality: norm, datapath, and solve output, bitwise.
        assert_eq!(prep2.frobenius_norm().to_bits(), fresh.frobenius_norm().to_bits(), "{precision:?}");
        assert_eq!(prep2.nnz(), fresh.nnz(), "{precision:?}");
        assert_eq!(prep2.value_bytes(), fresh.value_bytes(), "{precision:?}");
        let mut ws = LanczosWorkspace::new();
        let a = Solver::solve_detached(&prep2, 6, &opts, &mut ws, None).expect("incremental solve");
        let b = Solver::solve_detached(&fresh, 6, &opts, &mut ws, None).expect("scratch solve");
        assert_eq!(a.eigenvalues, b.eigenvalues, "{precision:?}: eigenvalues must be bitwise equal");
        assert_eq!(a.eigenvectors, b.eigenvectors, "{precision:?}: eigenvectors must be bitwise equal");
    }
}

#[test]
fn warm_kept_resolve_beats_cold_in_spmv_count() {
    let (base, canon) = acceptance_matrix();
    // Adaptive stopping makes iteration count (== SpMV count) the metric.
    let opts = SolveOptions { k: 1, adaptive_tol: Some(1e-8), ..Default::default() };
    let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
    let h = reg.register(base).expect("register");
    let prep = reg.prepared(h, &opts).expect("prepare");
    let mut ws = LanczosWorkspace::new();
    let first = Solver::solve_detached(&prep, 1, &opts, &mut ws, None).expect("first solve");
    assert!(!first.metrics.warm_started);
    reg.store_warm(h, 1, Precision::Float32, &first.eigenvectors[0]);

    // Small delta: well under warm_keep_tol, so the seed survives.
    let mut small = CooDelta::new(N, N);
    for i in 0..canon.nnz() {
        let (r, c) = (canon.rows[i] as usize, canon.cols[i] as usize);
        if r <= c && r < N / 1000 {
            small.upsert_sym(r, c, canon.vals[i] * 1.01);
        }
    }
    assert!(!small.is_empty());
    let rep = reg.update(h, small).expect("update");
    assert!(rep.warm_kept, "rel_delta {} must keep the seed", rep.rel_delta);

    let prep2 = reg.prepared(h, &opts).expect("refresh");
    let v1 = reg.warm_v1(h, 1, Precision::Float32);
    assert!(v1.is_some(), "seed retained across the generation bump");
    let warm = Solver::solve_detached(&prep2, 1, &opts, &mut ws, v1).expect("warm solve");
    assert!(warm.metrics.warm_started);
    let cold = Solver::solve_detached(&prep2, 1, &opts, &mut ws, None).expect("cold solve");
    assert!(!cold.metrics.warm_started);

    assert!(
        warm.metrics.spmv_count < cold.metrics.spmv_count,
        "warm-kept re-solve must use fewer SpMVs: warm {} vs cold {}",
        warm.metrics.spmv_count,
        cold.metrics.spmv_count
    );
    // Both agree on the dominant eigenvalue (finite-precision estimates).
    assert!(
        (warm.eigenvalues[0] - cold.eigenvalues[0]).abs() < 1e-3 * cold.eigenvalues[0].abs().max(1.0),
        "warm {} vs cold {}",
        warm.eigenvalues[0],
        cold.eigenvalues[0]
    );
}

#[test]
fn insertions_and_deletions_refresh_exactly_too() {
    // Structural edits (nnz changes) at n=2^12: boundaries may move, more
    // shards rebuild — but exactness must hold regardless.
    let n = 1 << 12;
    let base = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 777);
    let mut canon = base.clone();
    canon.canonicalize();
    let mut delta = CooDelta::new(n, n);
    // Delete a handful of existing edges and insert fresh ones.
    let mut removed = 0usize;
    for i in 0..canon.nnz() {
        let (r, c) = (canon.rows[i] as usize, canon.cols[i] as usize);
        if r < c && removed < 20 {
            delta.delete_sym(r, c);
            removed += 1;
        }
    }
    // Fewer insertions than deletions, so nnz must shrink even if every
    // inserted coordinate happens to exist already.
    for j in 0..13usize {
        let (r, c) = (2 * j, (7 * j + 3) % n);
        if r != c {
            delta.upsert_sym(r, c, 0.321);
        }
    }
    let mut mutated = canon.clone();
    {
        let mut d = delta.clone();
        d.canonicalize();
        mutated.apply_delta(&d);
    }
    assert_ne!(mutated.nnz(), canon.nnz(), "structural delta must change nnz");

    let opts = SolveOptions { k: 4, ..Default::default() };
    let reg = MatrixRegistry::default();
    let h = reg.register(base).expect("register");
    let _ = reg.prepared(h, &opts).expect("prepare");
    reg.update(h, delta).expect("update");
    let inc = reg.prepared(h, &opts).expect("refresh");

    let reg2 = MatrixRegistry::default();
    let h2 = reg2.register(mutated).expect("register mutated");
    let fresh = reg2.prepared(h2, &opts).expect("fresh prepare");

    let mut ws = LanczosWorkspace::new();
    let a = Solver::solve_detached(&inc, 4, &opts, &mut ws, None).expect("solve inc");
    let b = Solver::solve_detached(&fresh, 4, &opts, &mut ws, None).expect("solve fresh");
    assert_eq!(a.eigenvalues, b.eigenvalues);
    assert_eq!(a.eigenvectors, b.eigenvectors);
}

//! Matrix-resident serving: concurrent-solve correctness and registry
//! accounting, end to end.
//!
//! * N threads solving different K against one shared
//!   `Arc<PreparedMatrix>` must produce **bitwise identical** solutions to
//!   the same solves run serially — the property that lets worker replicas
//!   share one engine zero-copy.
//! * M jobs across P workers against one registered handle must trigger
//!   exactly one prepare (registry prepare-count telemetry == 1).
//! * `ServiceStats` counters must balance under a mixed valid/invalid
//!   workload: submitted == completed, failed == the invalid count, and
//!   the queue drains to zero.

use std::sync::Arc;
use topk_eigen::coordinator::service::{EigenService, QueuePolicy, ServiceConfig};
use topk_eigen::coordinator::{MatrixRegistry, RegistryConfig, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::graphs;
use topk_eigen::lanczos::LanczosWorkspace;

#[test]
fn concurrent_solves_on_one_shared_engine_match_serial_bitwise() {
    let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 77);
    for precision in [Precision::Float32, Precision::FixedQ1_15] {
        let opts = SolveOptions { precision, ..Default::default() };
        let mut solver = Solver::new(opts.clone());
        let prep = Arc::new(solver.prepare(&m).expect("prepare"));
        let ks: Vec<usize> = vec![2, 3, 5, 8, 13, 8, 5, 3];

        // Serial reference: same engine, one thread, one workspace.
        let serial: Vec<_> = {
            let mut ws = LanczosWorkspace::new();
            ks.iter().map(|&k| Solver::solve_detached(&prep, k, &opts, &mut ws, None).expect("serial solve")).collect()
        };

        // Concurrent: one thread per K, each with its own workspace, all
        // hammering the same Arc<PreparedMatrix> (and so the same CU pool).
        let concurrent: Vec<_> = std::thread::scope(|s| {
            let handles: Vec<_> = ks
                .iter()
                .map(|&k| {
                    let prep = Arc::clone(&prep);
                    let opts = opts.clone();
                    s.spawn(move || {
                        let mut ws = LanczosWorkspace::new();
                        Solver::solve_detached(&prep, k, &opts, &mut ws, None).expect("concurrent solve")
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("no panic")).collect()
        });

        for ((k, a), b) in ks.iter().zip(&serial).zip(&concurrent) {
            assert_eq!(a.eigenvalues, b.eigenvalues, "{precision:?} k={k}: eigenvalues must be bitwise equal");
            assert_eq!(a.eigenvectors, b.eigenvectors, "{precision:?} k={k}: eigenvectors must be bitwise equal");
            assert_eq!(a.metrics.spmv_count, b.metrics.spmv_count, "k={k}");
        }
    }
}

#[test]
fn m_jobs_across_p_workers_prepare_exactly_once() {
    let svc = EigenService::with_config(ServiceConfig {
        replicas: 4,
        policy: QueuePolicy::KBatched,
        ..Default::default()
    });
    let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 91);
    let handle = svc.register(m).expect("register");
    let ks: Vec<usize> = (0..24).map(|i| 2 + (i % 6)).collect();
    let tickets = svc.submit_handle_batch(handle, SolveOptions::default(), &ks);
    assert_eq!(tickets.len(), 24);
    for (id, t) in tickets {
        let r = t.wait();
        assert_eq!(r.id, id);
        assert!(r.outcome.is_ok(), "job {id}: {:?}", r.outcome.err());
    }
    let rstats = svc.registry().stats();
    assert_eq!(rstats.prepares, 1, "one registered handle, one engine key -> exactly one prepare: {rstats:?}");
    assert_eq!(rstats.engine_hits, 23, "every other job reuses the shared engine");
    assert_eq!(rstats.matrices, 1);
    assert!(rstats.resident_bytes > 0);
    let stats = svc.stats();
    assert_eq!(stats.submitted, 24);
    assert_eq!(stats.completed, 24);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.queue_depth, 0);
    svc.shutdown();
}

#[test]
fn stats_balance_under_mixed_valid_and_invalid_load() {
    let svc = EigenService::with_config(ServiceConfig { replicas: 3, ..Default::default() });
    let good = graphs::mesh2d(10, 10, 0.9, 0.02, 12); // n = 100
    let handle = svc.register(good.clone()).expect("register");
    let mut tickets = Vec::new();
    let mut expect_failed = 0u64;

    // Valid owned, handle, and batch jobs.
    for k in [2usize, 4, 6] {
        tickets.push(svc.submit(good.clone(), SolveOptions { k, ..Default::default() }).1);
        tickets.push(svc.submit_handle(handle, SolveOptions { k, ..Default::default() }).1);
    }
    for (_, t) in svc.submit_batch(good.clone(), SolveOptions::default(), &[3, 5]) {
        tickets.push(t);
    }
    // Invalid: bad k (0 and > n), non-square, unknown handle, and a batch
    // with one bad member.
    tickets.push(svc.submit(good.clone(), SolveOptions { k: 0, ..Default::default() }).1);
    expect_failed += 1;
    tickets.push(svc.submit(good.clone(), SolveOptions { k: 101, ..Default::default() }).1);
    expect_failed += 1;
    tickets.push(svc.submit(topk_eigen::sparse::CooMatrix::new(3, 4), SolveOptions::default()).1);
    expect_failed += 1;
    let foreign = MatrixRegistry::new(RegistryConfig::default()).register(good.clone()).unwrap();
    tickets.push(svc.submit_handle(foreign, SolveOptions { k: 2, ..Default::default() }).1);
    expect_failed += 1;
    for (_, t) in svc.submit_batch(good, SolveOptions::default(), &[4, 500]) {
        tickets.push(t);
    }
    expect_failed += 1; // the k = 500 member

    let total = tickets.len() as u64;
    let mut failed_seen = 0u64;
    for t in tickets {
        if t.wait().outcome.is_err() {
            failed_seen += 1;
        }
    }
    assert_eq!(failed_seen, expect_failed);
    let stats = svc.stats();
    assert_eq!(stats.submitted, total, "every ticket was counted as submitted");
    assert_eq!(stats.completed, total, "submitted == completed + (0 still queued)");
    assert_eq!(stats.failed, expect_failed);
    assert_eq!(stats.queue_depth, 0, "queue drains to zero");
    assert!(stats.max_queued_s <= stats.total_queued_s + 1e-9);
    svc.shutdown();
}

#[test]
fn evicted_engines_rebuild_transparently_under_budget_pressure() {
    // A registry budget far below two engines forces LRU eviction between
    // handle jobs; the service must keep answering correctly regardless.
    let svc = EigenService::with_config(ServiceConfig {
        replicas: 2,
        registry: RegistryConfig { budget_bytes: 1, ..Default::default() },
        ..Default::default()
    });
    let h1 = svc.register(graphs::mesh2d(9, 9, 0.9, 0.02, 1)).unwrap();
    let h2 = svc.register(graphs::mesh2d(9, 9, 0.9, 0.02, 2)).unwrap();
    for round in 0..3 {
        for &h in [h1, h2].iter() {
            let (_, t) = svc.submit_handle(h, SolveOptions { k: 3, ..Default::default() });
            let r = t.wait();
            assert!(r.outcome.is_ok(), "round {round}: {:?}", r.outcome.err());
        }
    }
    let rstats = svc.registry().stats();
    assert!(rstats.evictions >= 1, "budget pressure must evict: {rstats:?}");
    assert!(rstats.prepares >= 2, "evicted engines rebuild on demand");
    svc.shutdown();
}

//! Brute-force oracle pins for the streaming query datapath.
//!
//! * **Top-K SpMV**: the per-CU bounded-heap + fork/join merge must be
//!   **bitwise equal** to "full SpMV + stable sort by (score desc, index
//!   asc) + truncate" for every storage format, shard count, partition
//!   policy, and k — including tie-heavy score distributions, rows with no
//!   nonzeros, k = 0 (the deterministic empty answer), and k beyond the
//!   row count.
//! * **Batched SpMM**: `top_k_batch` must answer every member bitwise
//!   equal to an independent `top_k` call for every format and shard
//!   count — batching changes bytes streamed, never bits answered.
//! * **Early exit**: the bounded sweep (`top_k_with_bounds`, the path the
//!   service always takes) must skip provably-cold shards on a skewed
//!   fixture while staying bitwise equal to the full sweep.
//! * **Replica independence**: a 1-replica and an N-replica service must
//!   answer the same query stream bitwise identically.
//! * **PPR**: the reduced-precision power iteration must land within the
//!   documented per-format L1 tolerance of a dense f64 oracle run on the
//!   original (unquantized) matrix — on star, cycle, R-MAT n=2^10, and a
//!   graph with a dangling vertex.
//! * **Generation fencing**: queries racing `submit_update` deltas must
//!   each answer for one *complete* generation — bitwise equal to that
//!   generation's oracle, never a blend of two matrix states.

use std::sync::Arc;
use topk_eigen::coordinator::service::{EigenService, ServiceConfig};
use topk_eigen::coordinator::SolveOptions;
use topk_eigen::fixed::{Dataword, Precision};
use topk_eigen::graphs;
use topk_eigen::sparse::{
    normalize_frobenius, ppr_serial, top_k_serial, CooDelta, CooMatrix, CsrMatrix, PartitionPolicy, PprOptions,
    ShardedSpmv, TopKEntry,
};
use topk_eigen::with_precision;

/// Deterministic query vector in [-0.5, 0.5) — splitmix64 per element.
fn query_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    (0..n)
        .map(|_| {
            state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^= z >> 31;
            (z >> 40) as f32 / (1u64 << 24) as f32 - 0.5
        })
        .collect()
}

/// The registry's storage pipeline, reproduced through the public API:
/// canonicalize, Frobenius-normalize (`scale_value` per entry), quantize to
/// `V`. Returns the typed CSR plus the norm the service rescales Top-K
/// scores by. Value-stream bitwise equal to what `EigenService` serves.
fn stored_csr<V: Dataword>(m: &CooMatrix) -> (CsrMatrix<V>, f64) {
    let mut canon = m.clone();
    canon.canonicalize();
    let fro = normalize_frobenius(&mut canon);
    (canon.to_csr().to_precision::<V>(), fro)
}

/// Service-scale Top-K oracle: serial sort oracle on the stored values,
/// scores rescaled back to the original matrix scale exactly the way the
/// service does it (`(score as f64 * fro) as f32`).
fn expected_topk(m: &CooMatrix, x: &[f32], k: usize) -> Vec<TopKEntry> {
    let (csr, fro) = stored_csr::<f32>(m);
    let mut top = top_k_serial(&csr, x, k);
    for e in &mut top {
        e.score = (f64::from(e.score) * fro) as f32;
    }
    top
}

#[test]
fn top_k_is_bitwise_equal_to_the_sort_oracle_for_every_format_shard_and_k() {
    let n = 1usize << 8;
    let m = graphs::rmat(n, 6 * n, 0.57, 0.19, 0.19, 42);
    let x = query_vec(n, 7);
    for p in Precision::ALL {
        with_precision!(p, V => {
            let (csr, _) = stored_csr::<V>(&m);
            let csr = Arc::new(csr);
            for cus in [1usize, 3, 5, 8] {
                for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
                    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), cus, policy);
                    for k in [1usize, 8, n] {
                        let got = engine.top_k(&x, k);
                        let want = top_k_serial(csr.as_ref(), &x, k);
                        assert_eq!(got, want, "{} cus={cus} {policy:?} k={k}", p.name());
                    }
                }
            }
        });
    }
}

#[test]
fn top_k_survives_tie_floods_empty_rows_and_k_beyond_n() {
    // 64 rows, but only rows 0..6 hold entries, all with the same stored
    // value — the scores tie in droves (rows 6..64 additionally tie at
    // exactly 0.0) and selection is decided purely by the index
    // tie-break. Quantized formats collapse even more scores together.
    let n = 64usize;
    let mut coo = CooMatrix::new(n, n);
    for r in 0..6usize {
        for j in 0..8usize {
            let c = (r * 7 + j * 3) % n;
            coo.push(r, c, 0.25);
        }
    }
    let ones = vec![1.0f32; n];
    let tiny = query_vec(n, 3); // near-collisions without exact ties
    for p in Precision::ALL {
        with_precision!(p, V => {
            let (csr, _) = stored_csr::<V>(&coo);
            let csr = Arc::new(csr);
            for cus in [1usize, 3, 5, 8] {
                let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), cus, PartitionPolicy::BalancedNnz);
                // k spans: below / at / above the nonzero-row count, the
                // full row count, and past it (clamps to n).
                for k in [1usize, 3, 6, 20, n, n + 7] {
                    for x in [&ones, &tiny] {
                        let got = engine.top_k(x, k);
                        let want = top_k_serial(csr.as_ref(), x, k);
                        assert_eq!(got, want, "{} cus={cus} k={k}", p.name());
                        assert_eq!(got.len(), k.min(n));
                    }
                }
                // All-zero scores: a zero query vector ranks rows purely
                // by index through the total order.
                let zeros = vec![0.0f32; n];
                let got = engine.top_k(&zeros, 5);
                assert_eq!(got.iter().map(|e| e.index).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
                // k = 0: the deterministic empty answer, at every layer
                // (heap, merge, engine, batch) — never a panic, never a
                // partial result.
                assert!(engine.top_k(&ones, 0).is_empty(), "{} cus={cus}", p.name());
                assert!(engine.top_k_batch(&[ones.clone(), tiny.clone()], 0).iter().all(Vec::is_empty));
            }
        });
    }
}

#[test]
fn top_k_batch_answers_every_member_bitwise_equal_to_independent_queries() {
    // The batched-SpMM acceptance bar: for every storage format and shard
    // count, `top_k_batch` over b vectors must reproduce b independent
    // `top_k` calls bit for bit — the shared shard sweep changes how many
    // times the matrix bytes stream, never a single answer bit.
    let n = 1usize << 8;
    let m = graphs::rmat(n, 6 * n, 0.57, 0.19, 0.19, 43);
    let xs: Vec<Vec<f32>> = (0..4u64).map(|q| query_vec(n, 100 + q)).collect();
    for p in Precision::ALL {
        with_precision!(p, V => {
            let (csr, _) = stored_csr::<V>(&m);
            let csr = Arc::new(csr);
            for cus in [1usize, 3, 5, 8] {
                for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
                    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), cus, policy);
                    for k in [1usize, 8, n] {
                        let batch = engine.top_k_batch(&xs, k);
                        assert_eq!(batch.len(), xs.len());
                        for (q, x) in xs.iter().enumerate() {
                            assert_eq!(
                                batch[q],
                                engine.top_k(x, k),
                                "{} cus={cus} {policy:?} k={k} member {q}",
                                p.name()
                            );
                        }
                    }
                }
            }
        });
    }
}

/// A symmetric matrix whose score mass is concentrated in rows
/// `0..hot`: a ring of weight-8 edges inside the hot block, a ring of
/// weight-1e-4 edges among the rest. Under `EqualRows` sharding the hot
/// block lands in the first shard(s), so a positive query fills the
/// top-k there and the per-shard bound prunes the cold shards.
fn skewed_symmetric(n: usize, hot: usize) -> CooMatrix {
    let mut m = CooMatrix::new(n, n);
    for r in 0..hot {
        let c = (r + 1) % hot;
        m.push(r, c, 8.0);
        m.push(c, r, 8.0);
    }
    for r in hot..n {
        let c = hot + (r - hot + 1) % (n - hot);
        if c != r {
            m.push(r, c, 1e-4);
            m.push(c, r, 1e-4);
        }
    }
    m
}

#[test]
fn service_queries_skip_cold_shards_and_stay_bitwise_exact() {
    // The early-exit acceptance bar, through the full service path: the
    // bounded sweep (which the service always takes — row bounds are
    // cached in the registry) must skip shards on a skewed-norm fixture,
    // report them in `ServiceStats::shards_skipped`, and answer bitwise
    // what the plain sort oracle answers.
    let n = 512usize;
    let m = skewed_symmetric(n, 64);
    let x = vec![0.5f32; n];
    let opts = SolveOptions { cus: 8, partition: PartitionPolicy::EqualRows, ..Default::default() };
    let want = expected_topk(&m, &x, 8);
    assert!(want.iter().all(|e| (e.index as usize) < 64), "top-8 must live in the hot block");

    let svc = EigenService::with_config(ServiceConfig { replicas: 1, ..Default::default() });
    let h = svc.register(m.clone()).unwrap();
    let (_, t) = svc.submit_query(h, x.clone(), 8, opts.clone());
    let ans = t.wait().outcome.expect("query failed");
    assert_eq!(ans.entries, want, "early exit must not change a bit");
    let stats = svc.stats();
    assert!(stats.shards_skipped > 0, "skewed fixture must prune cold shards: {stats:?}");

    // The batched path takes the same bounds: per-member answers stay
    // bitwise equal to each member's own oracle, the row-bound table is
    // built once, and skipping still happens (pruning a shard requires
    // the bound to hold for *every* member).
    let x_quarter: Vec<f32> = x.iter().map(|v| v * 0.25).collect();
    let want_quarter = expected_topk(&m, &x_quarter, 8);
    let xs = vec![x.clone(), x_quarter, x];
    let tickets = svc.submit_query_batch(h, xs, 8, opts);
    for ((_, t), w) in tickets.into_iter().zip([&want, &want_quarter, &want]) {
        let a = t.wait().outcome.expect("batch member failed");
        assert_eq!(&a.entries, w);
    }
    let stats2 = svc.stats();
    assert!(stats2.shards_skipped > stats.shards_skipped, "{stats2:?}");
    let rstats = svc.registry().stats();
    assert_eq!(rstats.rowbound_builds, 1, "one row-bound pass serves every query: {rstats:?}");
    assert!(rstats.rowbound_hits >= 1, "{rstats:?}");
    svc.shutdown();
}

#[test]
fn one_and_many_replicas_answer_queries_bitwise_identically() {
    let n = 1usize << 8;
    let m = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 77);
    for p in Precision::ALL {
        let opts = SolveOptions { precision: p, ..Default::default() };
        let answers: Vec<Vec<Vec<TopKEntry>>> = [1usize, 3]
            .iter()
            .map(|&replicas| {
                let svc = EigenService::start(replicas);
                let h = svc.register(m.clone()).unwrap();
                let tickets: Vec<_> =
                    (0..6u64).map(|q| svc.submit_query(h, query_vec(n, q), 12, opts.clone()).1).collect();
                let out: Vec<Vec<TopKEntry>> = tickets
                    .into_iter()
                    .map(|t| t.wait().outcome.expect("query failed").entries)
                    .collect();
                svc.shutdown();
                out
            })
            .collect();
        assert_eq!(answers[0], answers[1], "{}: 1 vs 3 replicas must agree bitwise", p.name());
        // And both agree with the rescaled sort oracle.
        with_precision!(p, V => {
            let (csr, fro) = stored_csr::<V>(&m);
            for (q, ans) in answers[0].iter().enumerate() {
                let mut want = top_k_serial(&csr, &query_vec(n, q as u64), 12);
                for e in &mut want {
                    e.score = (f64::from(e.score) * fro) as f32;
                }
                assert_eq!(ans, &want, "{} query {q}", p.name());
            }
        });
    }
}

/// Dense f64 PPR oracle on the **original** (unnormalized, unquantized)
/// matrix: the same damped recurrence with dangling redistribution the
/// engine runs, but every operand in f64. Scale invariance of the
/// column-normalized iteration makes it directly comparable to the
/// engine's Frobenius-normalized stored values.
fn dense_ppr_f64(m: &CooMatrix, source: usize, alpha: f64) -> Vec<f64> {
    let n = m.nrows;
    let mut canon = m.clone();
    canon.canonicalize();
    let mut colsum = vec![0.0f64; n];
    for i in 0..canon.nnz() {
        colsum[canon.cols[i] as usize] += canon.vals[i] as f64;
    }
    let mut x = vec![0.0f64; n];
    x[source] = 1.0;
    for _ in 0..100_000 {
        let mut z = vec![0.0f64; n];
        let mut dangling_mass = 0.0f64;
        for j in 0..n {
            if colsum[j] == 0.0 {
                dangling_mass += x[j];
            } else {
                z[j] = x[j] / colsum[j];
            }
        }
        let mut y = vec![0.0f64; n];
        for i in 0..canon.nnz() {
            y[canon.rows[i] as usize] += canon.vals[i] as f64 * z[canon.cols[i] as usize];
        }
        let spread = alpha * dangling_mass / n as f64;
        let mut delta = 0.0f64;
        for i in 0..n {
            let xi = alpha * y[i] + spread + if i == source { 1.0 - alpha } else { 0.0 };
            delta += (xi - x[i]).abs();
            x[i] = xi;
        }
        if delta <= 1e-13 {
            break;
        }
    }
    x
}

/// Documented per-format L1 tolerance vs the dense f64 oracle (see the
/// accuracy table in `sparse::query`).
fn ppr_l1_tol(p: Precision) -> f64 {
    match p {
        Precision::Float32 => 1e-4,
        Precision::FixedQ1_31 | Precision::FixedQ2_30 => 1e-3,
        Precision::FixedQ1_15 => 8e-2,
    }
}

fn star_graph(spokes: usize) -> CooMatrix {
    let mut m = CooMatrix::new(spokes + 1, spokes + 1);
    for v in 1..=spokes {
        m.push(0, v, 1.0);
        m.push(v, 0, 1.0);
    }
    m
}

fn cycle_graph(n: usize) -> CooMatrix {
    let mut m = CooMatrix::new(n, n);
    for v in 0..n {
        let w = (v + 1) % n;
        m.push(v, w, 1.0);
        m.push(w, v, 1.0);
    }
    m
}

/// A 24-cycle plus one isolated (dangling) vertex 24.
fn dangling_graph() -> CooMatrix {
    let mut m = CooMatrix::new(25, 25);
    for v in 0..24usize {
        let w = (v + 1) % 24;
        m.push(v, w, 1.0);
        m.push(w, v, 1.0);
    }
    m
}

#[test]
fn ppr_matches_the_dense_f64_oracle_within_documented_tolerances() {
    let cases: Vec<(&str, CooMatrix, usize)> = vec![
        ("star", star_graph(32), 3),
        ("cycle", cycle_graph(40), 0),
        ("rmat", graphs::rmat(1 << 10, 8 << 10, 0.57, 0.19, 0.19, 9), 17),
        // Personalized on the isolated vertex itself, so its (dangling)
        // mass actually exists and must be redistributed every iteration.
        ("dangling", dangling_graph(), 24),
    ];
    for (name, m, source) in &cases {
        let oracle = dense_ppr_f64(m, *source, 0.85);
        let mass: f64 = oracle.iter().sum();
        assert!((mass - 1.0).abs() < 1e-9, "{name}: oracle mass {mass}");
        for p in Precision::ALL {
            with_precision!(p, V => {
                let (csr, _) = stored_csr::<V>(m);
                let opts = PprOptions { source: *source, alpha: 0.85, tol: 1e-7, max_iters: 2000 };
                let r = ppr_serial(&csr, &opts);
                if *name == "dangling" {
                    assert_eq!(r.dangling, 1, "{name} {}", p.name());
                    assert!(
                        r.scores.iter().all(|&s| s > 0.0),
                        "dangling-mass spread must reach every cycle vertex: {:?}",
                        &r.scores[..4]
                    );
                } else if *name != "rmat" {
                    assert_eq!(r.dangling, 0, "{name} {}", p.name());
                }
                let l1: f64 = r.scores.iter().zip(&oracle).map(|(&s, &o)| (s as f64 - o).abs()).sum();
                assert!(
                    l1 <= ppr_l1_tol(p),
                    "{name} {}: L1(engine - f64 oracle) = {l1:.3e} exceeds {:.0e}",
                    p.name(),
                    ppr_l1_tol(p)
                );
            });
        }
    }
}

#[test]
fn ppr_through_the_service_matches_the_direct_engine_bitwise() {
    // The service path (colsum cache, fences, worker threads) must add
    // nothing numerically: its answer is bitwise the serial recurrence.
    let m = cycle_graph(30);
    let opts = PprOptions { source: 4, ..Default::default() };
    let (csr, _) = stored_csr::<f32>(&m);
    let want = ppr_serial(&csr, &opts);
    let svc = EigenService::start(2);
    let h = svc.register(m).unwrap();
    let tickets: Vec<_> = (0..3).map(|_| svc.submit_ppr(h, opts.clone(), SolveOptions::default()).1).collect();
    for t in tickets {
        let ans = t.wait().outcome.expect("ppr failed");
        assert_eq!(ans.generation, 1);
        assert_eq!(ans.ppr, want);
    }
    svc.shutdown();
}

#[test]
fn racing_queries_always_observe_one_complete_generation() {
    let n = 1usize << 7;
    let m = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 301);
    let x = query_vec(n, 11);

    // Build three diagonal-upsert deltas, each aimed at the previous
    // generation's top rows so every update provably moves the ranking,
    // and precompute the exact expected answer of every generation.
    let mut canon = m.clone();
    canon.canonicalize();
    let mut cur = canon.clone();
    let mut oracles = vec![expected_topk(&cur, &x, 10)];
    let mut deltas: Vec<CooDelta> = Vec::new();
    for round in 0..3usize {
        let mut d = CooDelta::new(n, n);
        for e in &oracles[round] {
            d.upsert(e.index as usize, e.index as usize, 2.5 + round as f32 * 0.25);
        }
        let mut dc = d.clone();
        dc.canonicalize();
        cur.apply_delta(&dc);
        deltas.push(d);
        oracles.push(expected_topk(&cur, &x, 10));
        assert_ne!(oracles[round], oracles[round + 1], "round {round}: delta must move the ranking");
    }

    let svc = EigenService::with_config(ServiceConfig { replicas: 3, ..Default::default() });
    let h = svc.register(m).unwrap();

    // One thread hammers queries while the main thread walks the matrix
    // through generations 2..4. The fence guarantees every answer is the
    // oracle of *some* complete generation — never a torn mix.
    let answers = std::thread::scope(|s| {
        let worker = s.spawn(|| {
            let mut out = Vec::new();
            for _ in 0..40 {
                let (_, t) = svc.submit_query(h, x.clone(), 10, SolveOptions::default());
                out.push(t.wait().outcome.expect("query failed"));
            }
            out
        });
        for d in &deltas {
            std::thread::sleep(std::time::Duration::from_millis(3));
            let (_, t) = svc.submit_update(h, d.clone());
            t.wait().outcome.expect("update failed");
        }
        worker.join().expect("query thread panicked")
    });

    for a in &answers {
        let g = a.generation as usize;
        assert!((1..=4).contains(&g), "generation {g} out of range");
        assert_eq!(a.entries, oracles[g - 1], "generation {g}: answer must be that generation's oracle, bitwise");
    }
    // After all updates land, a fresh query must see the final state.
    let (_, t) = svc.submit_query(h, x.clone(), 10, SolveOptions::default());
    let last = t.wait().outcome.expect("final query");
    assert_eq!(last.generation, 4);
    assert_eq!(last.entries, oracles[3]);
    svc.shutdown();
}

//! Integration tests over the AOT bridge: HLO artifacts -> PJRT -> rust.
//!
//! These require `make artifacts`. If the artifact directory is missing
//! they fail with an actionable message — the build pipeline (Makefile
//! `test` target) always builds artifacts first.
//!
//! The whole file is gated on the `pjrt` cargo feature: the default build
//! substitutes pure-Rust runtime stubs (see `src/runtime/stub.rs`), so
//! there is nothing to integrate against without the feature.

#![cfg(feature = "pjrt")]

use std::sync::Arc;
use topk_eigen::graphs;
use topk_eigen::lanczos::Operator;
use topk_eigen::linalg::Tridiagonal;
use topk_eigen::runtime::{artifacts_dir, ArtifactRegistry, PjrtJacobi, PjrtSpmv, Runtime};
use topk_eigen::sparse::normalize_frobenius;
use topk_eigen::util::rng::Pcg64;

fn artifacts_ready() -> bool {
    let dir = artifacts_dir();
    ArtifactRegistry::all_files().iter().all(|f| dir.join(f).is_file())
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_ready() {
            eprintln!("SKIP: artifacts missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn all_registry_artifacts_exist_after_build() {
    require_artifacts!();
    // (When artifacts exist at all, the full registry must be present —
    // partial artifact sets indicate a drifted aot.py.)
    let dir = artifacts_dir();
    for f in ArtifactRegistry::all_files() {
        assert!(dir.join(&f).is_file(), "missing artifact {f}");
    }
}

#[test]
fn pjrt_spmv_matches_native_on_rmat() {
    require_artifacts!();
    let mut coo = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 5);
    normalize_frobenius(&mut coo);
    let csr = coo.to_csr();
    let rt = Arc::new(Runtime::cpu().expect("runtime"));
    let op = PjrtSpmv::new(rt, &coo).expect("load spmv artifact");
    let mut rng = Pcg64::new(3);
    for trial in 0..3 {
        let x: Vec<f32> = (0..coo.nrows).map(|_| rng.f32() - 0.5).collect();
        let mut y = vec![0.0f32; coo.nrows];
        op.apply(&x, &mut y);
        let expect = csr.spmv(&x);
        for i in 0..coo.nrows {
            assert!(
                (y[i] - expect[i]).abs() <= 1e-5 + 1e-4 * expect[i].abs(),
                "trial {trial} row {i}: pjrt {} vs native {}",
                y[i],
                expect[i]
            );
        }
    }
}

#[test]
fn pjrt_spmv_picks_larger_variant_when_needed() {
    require_artifacts!();
    let mut coo = graphs::mesh2d(64, 64, 0.9, 0.01, 2); // n = 4096 > 1024
    normalize_frobenius(&mut coo);
    let rt = Arc::new(Runtime::cpu().expect("runtime"));
    let op = PjrtSpmv::new(rt, &coo).expect("load spmv artifact");
    assert!(op.variant().n >= 4096);
    let x = vec![0.5f32; coo.nrows];
    let mut y = vec![0.0f32; coo.nrows];
    op.apply(&x, &mut y);
    assert_eq!(y, coo.to_csr().spmv(&x));
}

#[test]
fn pjrt_jacobi_matches_native_eigenvalues() {
    require_artifacts!();
    let rt = Runtime::cpu().expect("runtime");
    let mut rng = Pcg64::new(11);
    for k in [4usize, 8, 16, 32] {
        let t = Tridiagonal::new(
            (0..k).map(|_| rng.f64_range(-1.0, 1.0)).collect(),
            (0..k - 1).map(|_| rng.f64_range(-1.0, 1.0)).collect(),
        );
        let core = PjrtJacobi::new(&rt, k).expect("load jacobi artifact");
        assert_eq!(core.k_core, k);
        let (ev, vecs) = core.eigen(&t).expect("execute jacobi artifact");
        let native = topk_eigen::jacobi::jacobi_eigen(&t, topk_eigen::jacobi::JacobiMode::Cyclic, 1e-12);
        for i in 0..k {
            assert!(
                (ev[i] - native.eigenvalues[i]).abs() < 1e-4,
                "k={k} pair {i}: pjrt {} vs native {}",
                ev[i],
                native.eigenvalues[i]
            );
        }
        // Residual check against T itself.
        for j in 0..k {
            let x = vecs.col(j);
            let tx = t.matvec(&x);
            let res: f64 =
                tx.iter().zip(&x).map(|(&a, &b)| (a - ev[j] * b).powi(2)).sum::<f64>().sqrt();
            assert!(res < 1e-4, "k={k} pair {j} residual {res}");
        }
    }
}

#[test]
fn pjrt_jacobi_padding_filter_handles_small_k() {
    require_artifacts!();
    let rt = Runtime::cpu().expect("runtime");
    // k=6 runs on the k=8 core with 2 padded dimensions.
    let t = Tridiagonal::new(vec![0.9, -0.7, 0.5, -0.3, 0.2, -0.1], vec![0.05; 5]);
    let core = PjrtJacobi::new(&rt, 6).expect("load");
    assert_eq!(core.k_core, 8);
    let (ev, vecs) = core.eigen(&t).expect("run");
    assert_eq!(ev.len(), 6);
    assert_eq!(vecs.nrows, 6);
    let native = topk_eigen::jacobi::jacobi_eigen(&t, topk_eigen::jacobi::JacobiMode::Cyclic, 1e-12);
    for i in 0..6 {
        assert!((ev[i] - native.eigenvalues[i]).abs() < 1e-4, "pair {i}");
    }
}

#[test]
fn pjrt_lanczos_step_artifact_math() {
    require_artifacts!();
    let rt = Runtime::cpu().expect("runtime");
    let variant = ArtifactRegistry::SPMV_VARIANTS[0];
    let module = rt.load(&variant.lanczos_step_file()).expect("load lanczos_step");
    // Tiny diagonal matrix: M = diag(2), v = e0-normalized ones.
    let n = variant.n;
    let nnz = variant.nnz;
    let mut rows = vec![0i32; nnz];
    let mut cols = vec![0i32; nnz];
    let mut vals = vec![0f32; nnz];
    for i in 0..n {
        rows[i] = i as i32;
        cols[i] = i as i32;
        vals[i] = 2.0;
    }
    let inv = 1.0 / (n as f32).sqrt();
    let v = vec![inv; n];
    let v_prev = vec![0.0f32; n];
    let args = [
        xla::Literal::vec1(&rows),
        xla::Literal::vec1(&cols),
        xla::Literal::vec1(&vals),
        xla::Literal::vec1(&v),
        xla::Literal::vec1(&v_prev),
        xla::Literal::scalar(0.0f32),
    ];
    let out = module.run(&args).expect("run");
    assert_eq!(out.len(), 2);
    let w: Vec<f32> = out[0].to_vec().expect("w");
    let alpha = out[1].get_first_element::<f32>().expect("alpha");
    // M v = 2v; alpha = <2v, v> = 2; w' = 2v - 2v = 0.
    assert!((alpha - 2.0).abs() < 1e-4, "alpha {alpha}");
    assert!(w.iter().all(|&x| x.abs() < 1e-4), "w' should vanish");
}

#[test]
fn solver_pjrt_engine_end_to_end() {
    require_artifacts!();
    use topk_eigen::coordinator::{verify, Engine, SolveOptions, Solver};
    let adj = graphs::rmat(1 << 9, 6 << 9, 0.57, 0.19, 0.19, 21);
    let mut native = Solver::new(SolveOptions { k: 8, ..Default::default() });
    let mut pjrt = Solver::new(SolveOptions { k: 8, engine: Engine::Pjrt, ..Default::default() });
    let sn = native.solve(&adj).expect("native");
    let sp = pjrt.solve(&adj).expect("pjrt");
    assert_eq!(sp.metrics.engine_used, "pjrt");
    for i in 0..sn.k().min(sp.k()) {
        assert!(
            (sn.eigenvalues[i] - sp.eigenvalues[i]).abs() < 1e-3 * sn.eigenvalues[0].abs().max(1.0),
            "pair {i}: native {} vs pjrt {}",
            sn.eigenvalues[i],
            sp.eigenvalues[i]
        );
    }
    let r = verify::verify(&adj, &sp);
    assert!(r.mean_angle_deg > 89.0);
}

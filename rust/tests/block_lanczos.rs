//! Property suite for the block-Lanczos engine: the block path (one fused
//! matrix stream per iteration applying SpMV + Paige block axpy + block
//! dots + reorthogonalization to all b columns) must reproduce the
//! single-vector top-K Ritz values across every storage precision, shard
//! count, partition policy, and block width — and must resolve clustered
//! eigenvalues the single-vector recurrence cannot.
//!
//! Documented per-precision agreement tolerances (relative to the leading
//! Ritz value, both paths run to a 40-vector adaptive budget at full
//! reorthogonalization):
//!
//! * `f32`: 5e-4 — both bases are f32-quantized; the paths differ by
//!   Krylov-space shape (degree-j block vs degree-jb single), summation
//!   order, and Gram-Schmidt variant, all of which land orders below this.
//! * `q1.31` / `q2.30`: 1e-3 — 32-bit fixed storage adds ~ulp/sqrt(n)
//!   quantization noise per stored word on top of the f32 figure.
//! * `q1.15`: 2e-2 — 16-bit words carry ~2^-15 value noise; Ritz values
//!   of a quantized basis track the true spectrum at the ~1e-3 scale on
//!   normalized 256-vertex graphs, bounded here with a wide margin.

use std::sync::Arc;
use topk_eigen::fixed::{Dataword, Q1_15, Q1_31, Q2_30};
use topk_eigen::graphs;
use topk_eigen::lanczos::{block_lanczos_typed, lanczos_typed, BlockLanczosResult, LanczosResult};
use topk_eigen::lanczos::{LanczosOptions, ReorthPolicy, ShardedSpmv};
use topk_eigen::sparse::{normalize_frobenius, CooMatrix, CsrMatrix, PartitionPolicy};

const SHARD_COUNTS: [usize; 4] = [1, 3, 5, 8];
const POLICIES: [PartitionPolicy; 2] = [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz];
const BLOCK_WIDTHS: [usize; 3] = [1, 2, 4];
const K: usize = 4;

/// Frobenius-normalized RMAT test graph (entries in (-1,1), as the typed
/// datapath requires).
fn test_graph(n: usize, seed: u64) -> CsrMatrix {
    let mut g = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, seed);
    normalize_frobenius(&mut g);
    g.to_csr()
}

/// A 40-vector adaptive budget at full reorthogonalization: both paths
/// converge the top-K of a 256-vertex graph far past the agreement
/// tolerance before the budget runs out (adaptive stop at 1e-12 relative
/// stabilization just trims already-converged tails).
fn run_opts(k: usize, b: usize) -> LanczosOptions {
    LanczosOptions {
        k,
        block_size: b,
        reorth: ReorthPolicy::Every,
        max_iters: 40,
        ritz_tol: 1e-12,
        ..Default::default()
    }
}

fn check_block_agreement<V: Dataword>(csr: &Arc<CsrMatrix>, tol_rel: f64) {
    let typed: Arc<CsrMatrix<V>> = Arc::new(csr.to_precision::<V>());
    // Single-vector reference on the serial (default-fallback) operator.
    let single: LanczosResult<V> = lanczos_typed(typed.as_ref(), &run_opts(K, 1));
    let want = single.tridiag.top_k_by_magnitude(K);
    let scale = want[0].abs().max(1e-30);
    for cus in SHARD_COUNTS {
        for policy in POLICIES {
            let engine = ShardedSpmv::with_own_pool(Arc::clone(&typed), cus, policy);
            for b in BLOCK_WIDTHS {
                let label = format!("{}/cus{cus}/{policy:?}/b{b}", V::NAME);
                let bres: BlockLanczosResult<V> = block_lanczos_typed(&engine, &run_opts(K, b));
                // Stream-once accounting holds at every width.
                assert_eq!(bres.spmv_count, bres.matrix_passes * b, "{label}");
                assert_eq!(bres.fused_sweeps, bres.matrix_passes, "{label}");
                let top = bres.band.top_k_by_magnitude(K);
                for i in 0..K {
                    assert!(
                        (top[i] - want[i]).abs() <= tol_rel * scale,
                        "{label}: ritz[{i}] {} vs {} (tol {tol_rel} rel)",
                        top[i],
                        want[i]
                    );
                }
            }
        }
    }
}

#[test]
fn block_matches_single_vector_ritz_f32_storage() {
    let csr = Arc::new(test_graph(1 << 8, 61));
    check_block_agreement::<f32>(&csr, 5e-4);
}

#[test]
fn block_matches_single_vector_ritz_q131_storage() {
    let csr = Arc::new(test_graph(1 << 8, 62));
    check_block_agreement::<Q1_31>(&csr, 1e-3);
}

#[test]
fn block_matches_single_vector_ritz_q230_storage() {
    let csr = Arc::new(test_graph(1 << 8, 63));
    check_block_agreement::<Q2_30>(&csr, 1e-3);
}

#[test]
fn block_matches_single_vector_ritz_q115_storage() {
    let csr = Arc::new(test_graph(1 << 8, 64));
    check_block_agreement::<Q1_15>(&csr, 2e-2);
}

/// The clustered fixture: a near-degenerate dominant pair (gap 1e-4) over
/// a well-separated tail. Exact eigenvalues are the f32-stored diagonal
/// entries, so convergence is measured against ground truth.
fn clustered_diag() -> (Arc<CsrMatrix>, f64, f64) {
    let n = 64;
    let mut m = CooMatrix::new(n, n);
    m.push(0, 0, 0.9);
    m.push(1, 1, 0.9 - 1e-4);
    let mut tail = 0.3f32;
    for i in 2..n {
        m.push(i, i, tail);
        tail *= 0.9;
    }
    (Arc::new(m.to_csr()), f64::from(0.9f32), f64::from(0.9f32 - 1e-4))
}

fn cluster_resolved(top: &[f64], l0: f64, l1: f64) -> bool {
    top.len() == 2 && (top[0] - l0).abs() < 2e-5 && (top[1] - l1).abs() < 2e-5
}

#[test]
fn block_resolves_clustered_pair_in_fewer_matrix_passes() {
    // Single-vector Lanczos cannot separate a 1e-4-gap cluster from the
    // deterministic start: the Krylov space mixes e0 and e1 into one
    // direction and the component separating them grows by only
    // ~(1 + 1e-4) per pass from rounding-noise scale, so the second Ritz
    // value stays at the tail (~0.3) for any realistic budget. A width-2
    // block spans two independent directions through the cluster from
    // pass one and converges both members at the tail-gap rate.
    let (csr, l0, l1) = clustered_diag();
    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), 3, PartitionPolicy::BalancedNnz);

    const SINGLE_CAP: usize = 24;
    let mut single_passes = SINGLE_CAP + 1; // sentinel: never resolved
    for p in 2..=SINGLE_CAP {
        // Fixed schedule: exactly p matrix passes, p-dim Krylov space.
        let r: LanczosResult = lanczos_typed(
            &engine,
            &LanczosOptions { k: p, reorth: ReorthPolicy::Every, ..Default::default() },
        );
        if cluster_resolved(&r.tridiag.top_k_by_magnitude(2), l0, l1) {
            single_passes = r.matrix_passes;
            break;
        }
    }

    let mut block_passes = 0;
    for p in 1..=12 {
        // Fixed schedule at width 2: k = 2p rounds to exactly p passes.
        let r: BlockLanczosResult = block_lanczos_typed(
            &engine,
            &LanczosOptions { k: 2 * p, block_size: 2, reorth: ReorthPolicy::Every, ..Default::default() },
        );
        assert_eq!(r.matrix_passes, p, "fixed block schedule must run exactly p passes");
        if cluster_resolved(&r.band.top_k_by_magnitude(2), l0, l1) {
            block_passes = r.matrix_passes;
            break;
        }
    }

    assert!(block_passes > 0, "b=2 never resolved the cluster within 12 passes");
    assert!(
        block_passes < single_passes,
        "b=2 must resolve the near-degenerate pair in strictly fewer matrix passes \
         (block {block_passes} vs single {single_passes}, cap {SINGLE_CAP})"
    );
}

#[test]
fn service_block_solves_warm_start_from_the_ritz_panel() {
    // End-to-end block serving: repeated (handle, k) block jobs fetch the
    // cached Ritz-front panel, and the answers stay consistent with the
    // cold solve.
    use topk_eigen::coordinator::service::{EigenService, ServiceConfig};
    use topk_eigen::coordinator::{RegistryConfig, SolveOptions};
    let m = graphs::rmat(1 << 8, 8 << 8, 0.57, 0.19, 0.19, 77);
    let svc = EigenService::with_config(ServiceConfig {
        replicas: 1,
        registry: RegistryConfig { warm_start: true, ..Default::default() },
        ..Default::default()
    });
    let handle = svc.register(m).unwrap();
    // Adaptive mode so both solves run to Ritz stabilization: the warm
    // repeat starts inside the converged subspace and may stop earlier,
    // but both land on the same leading spectrum.
    let opts = SolveOptions {
        k: 8,
        block_size: 2,
        reorth: ReorthPolicy::Every,
        adaptive_tol: Some(1e-8),
        ..Default::default()
    };
    let (_, t1) = svc.submit_handle(handle, opts.clone());
    let cold = t1.wait().outcome.unwrap();
    assert_eq!(cold.metrics.block_size, 2);
    assert_eq!(cold.metrics.spmv_count, cold.metrics.matrix_passes * 2);
    assert!(!cold.metrics.warm_started);
    assert_eq!(cold.k(), 8);

    let (_, t2) = svc.submit_handle(handle, opts);
    let warm = t2.wait().outcome.unwrap();
    assert_eq!(warm.k(), 8);
    // The repeat fetched the stored panel (warm_hits ticks even if the
    // solve later fell back cold on a truncation retry).
    assert!(svc.registry().stats().warm_hits >= 1, "repeat block job must fetch the Ritz panel");
    // Leading pairs of two stabilized solves agree; trailing pairs of an
    // adaptive run are subspace-dependent and are covered by the
    // engine-level oracles above.
    let lead = cold.eigenvalues[0].abs().max(1e-30);
    for i in 0..3 {
        assert!(
            (warm.eigenvalues[i] - cold.eigenvalues[i]).abs() < 2e-2 * lead,
            "pair {i}: warm {} vs cold {}",
            warm.eigenvalues[i],
            cold.eigenvalues[i]
        );
    }
    svc.shutdown();
}

//! Property suite for the fused single-sweep Lanczos datapath: the fused
//! path (shard-parallel SpMV + axpy + dot + blocked classical-GS reorth)
//! must produce the same tridiagonal as the unfused serial-pass reference
//! across every storage precision, shard count, and reorthogonalization
//! policy — including breakdown / early-truncation cases.
//!
//! Tolerances: without reorthogonalization the two paths perform
//! structurally identical arithmetic (only the shard-merge reduction order
//! differs — f64-noise level, bound 1e-10; bitwise on a single f32 shard).
//! On reorthogonalization iterations the paths differ by Gram-Schmidt
//! variant: blocked classical GS computes every projection from the
//! pre-`alpha v` residual, modified GS from the sequentially updated one —
//! the resulting vectors differ by O(eps_f32) *within the basis span*, so
//! later coefficients drift at the low-1e-9 scale on normalized inputs.
//! Fixed-point storage adds quantization cliffs on top (a tiny difference
//! in `w` near a rounding boundary moves a stored word by one ulp,
//! shifting later coefficients by ~ulp/sqrt(n) each); those bounds are
//! ulp-scaled.

use std::sync::Arc;
use topk_eigen::fixed::{Dataword, Q1_15, Q1_31, Q2_30};
use topk_eigen::graphs;
use topk_eigen::lanczos::{lanczos_typed, LanczosOptions, LanczosResult, ReorthPolicy, ShardedSpmv};
use topk_eigen::sparse::{normalize_frobenius, CooMatrix, CsrMatrix, PartitionPolicy};

const SHARD_COUNTS: [usize; 4] = [1, 3, 5, 8];
const POLICIES: [ReorthPolicy; 4] =
    [ReorthPolicy::None, ReorthPolicy::Every, ReorthPolicy::EveryN(2), ReorthPolicy::EveryN(3)];

/// Frobenius-normalized RMAT test graph (entries in (-1,1), as the typed
/// datapath requires).
fn test_graph(n: usize, seed: u64) -> CsrMatrix {
    let mut g = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, seed);
    normalize_frobenius(&mut g);
    g.to_csr()
}

/// Tridiagonal agreement bound for one storage format and reorth policy
/// (see the module docs for the error model). The reorth base is
/// calibrated against a NumPy reference simulation of both Gram-Schmidt
/// variants on Frobenius-normalized n=512 graphs, which measures worst
/// drift ~1.6e-8 — the bound keeps a ~6x margin.
fn bound<V: Dataword>(n: usize, k: usize, reorth: ReorthPolicy) -> f64 {
    let base = if reorth == ReorthPolicy::None { 1e-10 } else { 1e-7 };
    if V::IS_FIXED {
        base + 8.0 * (k as f64) * V::ulp() / (n as f64).sqrt()
    } else {
        base
    }
}

fn assert_tridiag_match<V: Dataword>(fused: &LanczosResult<V>, plain: &LanczosResult<V>, tol: f64, label: &str) {
    assert_eq!(fused.breakdown_at, plain.breakdown_at, "{label}: breakdown mismatch");
    assert_eq!(fused.k(), plain.k(), "{label}: k mismatch");
    for i in 0..fused.k() {
        let (a, b) = (fused.tridiag.alpha[i], plain.tridiag.alpha[i]);
        assert!((a - b).abs() <= tol, "{label}: alpha[{i}] {a} vs {b} (tol {tol})");
    }
    for i in 0..fused.tridiag.beta.len() {
        let (a, b) = (fused.tridiag.beta[i], plain.tridiag.beta[i]);
        assert!((a - b).abs() <= tol, "{label}: beta[{i}] {a} vs {b} (tol {tol})");
    }
}

fn check_format<V: Dataword>(csr: &Arc<CsrMatrix>, k: usize) {
    let typed: Arc<CsrMatrix<V>> = Arc::new(csr.to_precision::<V>());
    let n = csr.nrows;
    for cus in SHARD_COUNTS {
        let engine = ShardedSpmv::with_own_pool(Arc::clone(&typed), cus, PartitionPolicy::BalancedNnz);
        for reorth in POLICIES {
            let tol = bound::<V>(n, k, reorth);
            let label = format!("{}/cus{cus}/{}", V::NAME, reorth.name());
            let fused: LanczosResult<V> =
                lanczos_typed(&engine, &LanczosOptions { k, reorth, fused: true, ..Default::default() });
            let plain: LanczosResult<V> =
                lanczos_typed(&engine, &LanczosOptions { k, reorth, fused: false, ..Default::default() });
            assert_tridiag_match(&fused, &plain, tol, &label);
            // Telemetry: the fused path runs one fused sweep per SpMV; the
            // unfused path runs none.
            assert_eq!(fused.fused_sweeps, fused.spmv_count, "{label}");
            assert_eq!(plain.fused_sweeps, 0, "{label}");
            assert!(plain.vector_passes > fused.vector_passes, "{label}: fusion must reduce passes");
        }
    }
}

#[test]
fn fused_matches_unfused_f32_storage() {
    let csr = Arc::new(test_graph(1 << 9, 11));
    check_format::<f32>(&csr, 16);
}

#[test]
fn fused_matches_unfused_q131_storage() {
    let csr = Arc::new(test_graph(1 << 9, 12));
    check_format::<Q1_31>(&csr, 16);
}

#[test]
fn fused_matches_unfused_q230_storage() {
    let csr = Arc::new(test_graph(1 << 9, 13));
    check_format::<Q2_30>(&csr, 16);
}

#[test]
fn fused_matches_unfused_q115_storage() {
    let csr = Arc::new(test_graph(1 << 9, 14));
    check_format::<Q1_15>(&csr, 16);
}

#[test]
fn fused_is_bitwise_without_reorth_on_single_shard_f32() {
    // With one shard and no basis projections, the fused sweep kernels
    // share the serial 4-lane structure exactly — the tridiagonal must be
    // bitwise identical.
    let csr = Arc::new(test_graph(1 << 8, 21));
    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), 1, PartitionPolicy::EqualRows);
    let opts = |fused| LanczosOptions { k: 12, reorth: ReorthPolicy::None, fused, ..Default::default() };
    let fused: LanczosResult = lanczos_typed(&engine, &opts(true));
    let plain: LanczosResult = lanczos_typed(&engine, &opts(false));
    for i in 0..12 {
        assert_eq!(
            fused.tridiag.alpha[i].to_bits(),
            plain.tridiag.alpha[i].to_bits(),
            "alpha[{i}]: {} vs {}",
            fused.tridiag.alpha[i],
            plain.tridiag.alpha[i]
        );
    }
    for i in 0..fused.tridiag.beta.len() {
        assert_eq!(fused.tridiag.beta[i].to_bits(), plain.tridiag.beta[i].to_bits(), "beta[{i}]");
    }
    // And the stored bases agree word-for-word.
    for i in 0..fused.basis.len() {
        assert_eq!(&fused.basis[i], &plain.basis[i], "row {i}");
    }
}

#[test]
fn fused_is_deterministic_across_shard_counts_vs_serial_operator() {
    // Different CU counts change the reduction partitioning but must stay
    // within floating noise of the serial (default-fallback) operator.
    let csr = Arc::new(test_graph(1 << 9, 31));
    let reference: LanczosResult =
        lanczos_typed(csr.as_ref(), &LanczosOptions { k: 12, reorth: ReorthPolicy::EveryN(2), ..Default::default() });
    for cus in SHARD_COUNTS {
        let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), cus, PartitionPolicy::BalancedNnz);
        let res: LanczosResult =
            lanczos_typed(&engine, &LanczosOptions { k: 12, reorth: ReorthPolicy::EveryN(2), ..Default::default() });
        // Both runs are the fused CGS path: only the reduction partitioning
        // differs, so the agreement is much tighter than fused-vs-unfused.
        assert_tridiag_match(&res, &reference, 1e-9, &format!("cus{cus}"));
    }
}

#[test]
fn breakdown_and_truncation_match() {
    // Identity at n = 16: the uniform start is 0.25 per element (an exact
    // dyadic), so w - alpha*v vanishes *exactly* in f32 and both paths
    // must break down at iteration 1 with alpha = 1 for any shard count.
    let mut eye = CooMatrix::new(16, 16);
    for i in 0..16 {
        eye.push(i, i, 1.0);
    }
    let eye = Arc::new(eye.to_csr());
    for cus in [1usize, 3] {
        let engine = ShardedSpmv::with_own_pool(Arc::clone(&eye), cus, PartitionPolicy::EqualRows);
        for fused in [true, false] {
            let res: LanczosResult = lanczos_typed(&engine, &LanczosOptions { k: 8, fused, ..Default::default() });
            assert_eq!(res.breakdown_at, Some(1), "cus={cus} fused={fused}");
            assert_eq!(res.k(), 1, "cus={cus} fused={fused}");
            assert!((res.tridiag.alpha[0] - 1.0).abs() < 1e-6);
            assert_eq!(res.basis.len(), 1, "basis truncated with the recurrence");
        }
    }

    // Rank-2 spectrum: the Krylov space closes after 2 iterations up to
    // f32 rounding. Whether the residual dips under the breakdown
    // tolerance is arithmetic-dependent — what must hold is that both
    // paths make the *same* call and agree on the leading coefficients.
    let mut two = CooMatrix::new(32, 32);
    for i in 0..32 {
        two.push(i, i, if i % 2 == 0 { 0.5 } else { -0.25 });
    }
    let two = Arc::new(two.to_csr());
    let engine = ShardedSpmv::with_own_pool(Arc::clone(&two), 5, PartitionPolicy::BalancedNnz);
    let fused: LanczosResult = lanczos_typed(&engine, &LanczosOptions { k: 4, fused: true, ..Default::default() });
    let plain: LanczosResult = lanczos_typed(&engine, &LanczosOptions { k: 4, fused: false, ..Default::default() });
    for i in 0..2 {
        assert!(
            (fused.tridiag.alpha[i] - plain.tridiag.alpha[i]).abs() < 1e-9,
            "rank-2 alpha[{i}]: {} vs {}",
            fused.tridiag.alpha[i],
            plain.tridiag.alpha[i]
        );
    }
    assert!(
        (fused.tridiag.beta[0] - plain.tridiag.beta[0]).abs() < 1e-9,
        "rank-2 beta[0]: {} vs {}",
        fused.tridiag.beta[0],
        plain.tridiag.beta[0]
    );
}

#[test]
fn fused_spectra_survive_the_full_solve_path() {
    // End-to-end: SolveOptions.fuse toggles the datapath; eigenvalues must
    // agree to solver tolerance either way (the --no-fuse escape hatch).
    use topk_eigen::coordinator::{SolveOptions, Solver};
    let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 41);
    let mut fused_solver = Solver::new(SolveOptions { k: 8, fuse: true, ..Default::default() });
    let mut plain_solver = Solver::new(SolveOptions { k: 8, fuse: false, ..Default::default() });
    let a = fused_solver.solve(&m).unwrap();
    let b = plain_solver.solve(&m).unwrap();
    assert_eq!(a.k(), b.k());
    // The Frobenius rescale amplifies the CGS-vs-MGS drift back to the
    // input's scale; 1e-6 relative is still far below solver accuracy.
    for i in 0..a.k() {
        assert!(
            (a.eigenvalues[i] - b.eigenvalues[i]).abs() < 1e-6 * a.eigenvalues[0].abs().max(1.0),
            "pair {i}: {} vs {}",
            a.eigenvalues[i],
            b.eigenvalues[i]
        );
    }
    assert_eq!(a.metrics.fused_sweeps, a.metrics.spmv_count);
    assert_eq!(b.metrics.fused_sweeps, 0);
    assert!(b.metrics.vector_passes > a.metrics.vector_passes);
}

#[test]
fn fused_respects_custom_start_vectors() {
    let csr = Arc::new(test_graph(1 << 8, 51));
    let v1: Vec<f32> = (0..csr.nrows).map(|i| ((i as f32) * 0.37).sin() + 1.5).collect();
    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), 5, PartitionPolicy::BalancedNnz);
    let mk = |fused| LanczosOptions {
        k: 10,
        reorth: ReorthPolicy::Every,
        fused,
        v1: Some(v1.clone()),
        ..Default::default()
    };
    let fused: LanczosResult = lanczos_typed(&engine, &mk(true));
    let plain: LanczosResult = lanczos_typed(&engine, &mk(false));
    assert_tridiag_match(&fused, &plain, bound::<f32>(csr.nrows, 10, ReorthPolicy::Every), "custom v1");
}

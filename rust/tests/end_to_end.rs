//! Cross-module integration: solver vs baseline agreement, catalog-suite
//! solves, the service, and the MatrixMarket IO loop.

use std::sync::Arc;
use topk_eigen::coordinator::service::EigenService;
use topk_eigen::coordinator::{verify, SolveOptions, Solver};
use topk_eigen::graphs;
use topk_eigen::iram::{iram, IramOptions};
use topk_eigen::lanczos::{ReorthPolicy, ShardedSpmv};
use topk_eigen::sparse::{self, PartitionPolicy};
use topk_eigen::util::pool::ThreadPool;

/// The two independent solvers (single-pass Lanczos+Jacobi vs restarted
/// Lanczos) must agree on the dominant eigenvalues of a well-separated
/// spectrum.
#[test]
fn solver_and_iram_agree_on_dominant_pairs() {
    let mut adj = graphs::scale_free_ba(1500, 6, 3);
    sparse::normalize_frobenius(&mut adj);
    let csr = adj.to_csr();

    let mut solver = Solver::new(SolveOptions {
        k: 16,
        reorth: ReorthPolicy::Every,
        skip_normalize: true,
        ..Default::default()
    });
    let sol = solver.solve(&adj).expect("solve");

    let ir = iram(&csr, &IramOptions { k: 6, tol: 1e-9, ..Default::default() });
    assert!(ir.converged);
    // Single-pass Lanczos gives *approximate* Ritz pairs: the dominant one
    // converges fast, deeper ones carry O(percent) error — exactly the
    // accuracy regime the paper's Fig 11 characterizes. Compare the top
    // pair tightly and the next two loosely.
    assert!(
        (sol.eigenvalues[0] - ir.eigenvalues[0]).abs() < 2e-3 * ir.eigenvalues[0].abs(),
        "pair 0: lanczos+jacobi {} vs iram {}",
        sol.eigenvalues[0],
        ir.eigenvalues[0]
    );
    // Power-law spectra carry near-symmetric +-lambda pairs whose order
    // under |.| can swap between approximate methods; compare magnitudes.
    for i in 1..3 {
        assert!(
            (sol.eigenvalues[i].abs() - ir.eigenvalues[i].abs()).abs() < 0.08 * ir.eigenvalues[i].abs(),
            "pair {i}: lanczos+jacobi {} vs iram {}",
            sol.eigenvalues[i],
            ir.eigenvalues[i]
        );
    }
}

#[test]
fn catalog_suite_solves_cleanly_at_tiny_scale() {
    for (i, e) in graphs::catalog().into_iter().enumerate() {
        let g = e.generate(2048);
        let mut solver = Solver::new(SolveOptions { k: 6, ..Default::default() });
        let sol = solver.solve(&g).unwrap_or_else(|err| panic!("{} failed: {err}", e.id));
        assert!(sol.k() >= 1, "{}: no pairs", e.id);
        let r = verify::verify(&g, &sol);
        assert!(r.mean_angle_deg > 88.0, "{}: angle {}", e.id, r.mean_angle_deg);
        // Eigenvalues bounded by the Frobenius norm.
        for (lambda, _) in sol.pairs() {
            assert!(lambda.abs() <= sol.frobenius_norm * 1.001, "{}: |{lambda}| > fro", e.id);
        }
        let _ = i;
    }
}

#[test]
fn sharded_iram_equals_serial_iram() {
    let mut adj = graphs::rmat(1 << 9, 6 << 9, 0.57, 0.19, 0.19, 17);
    sparse::normalize_frobenius(&mut adj);
    let csr = Arc::new(adj.to_csr());
    let pool = Arc::new(ThreadPool::new(4));
    let sharded = ShardedSpmv::new(Arc::clone(&csr), 4, PartitionPolicy::BalancedNnz, pool);
    let a = iram(csr.as_ref(), &IramOptions { k: 4, tol: 1e-8, ..Default::default() });
    let b = iram(&sharded, &IramOptions { k: 4, tol: 1e-8, ..Default::default() });
    for i in 0..4 {
        assert!(
            (a.eigenvalues[i] - b.eigenvalues[i]).abs() < 1e-6,
            "pair {i}: serial {} vs sharded {}",
            a.eigenvalues[i],
            b.eigenvalues[i]
        );
    }
}

#[test]
fn mtx_round_trip_preserves_solution() {
    let dir = std::env::temp_dir().join("topk-e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("graph.mtx");
    let adj = graphs::mesh2d(20, 20, 0.9, 0.02, 7);
    sparse::write_matrix_market(&path, &adj).unwrap();
    let re = sparse::read_matrix_market(&path).unwrap();
    let mut s1 = Solver::new(SolveOptions { k: 4, ..Default::default() });
    let mut s2 = Solver::new(SolveOptions { k: 4, ..Default::default() });
    let a = s1.solve(&adj).unwrap();
    let b = s2.solve(&re).unwrap();
    for i in 0..4 {
        assert!((a.eigenvalues[i] - b.eigenvalues[i]).abs() < 1e-6);
    }
}

#[test]
fn service_mixed_workload_under_load() {
    let svc = EigenService::start(3);
    let mut tickets = Vec::new();
    for i in 0..9u64 {
        let m = graphs::erdos_renyi(256 + (i as usize) * 32, 2048, i);
        let (_, t) = svc.submit(m, SolveOptions { k: 3 + (i as usize % 3), ..Default::default() });
        tickets.push(t);
    }
    let mut done = 0;
    for t in tickets {
        let r = t.wait();
        assert!(r.outcome.is_ok(), "job {} failed: {:?}", r.id, r.outcome.err());
        done += 1;
    }
    assert_eq!(done, 9);
}

#[test]
fn batched_service_multi_k_matches_single_submissions() {
    // The same-matrix multi-K fast path (one prepare + one sharded engine
    // shared across the batch) must be numerically identical to fresh
    // single-job solves, and the telemetry must account for every member.
    let svc = EigenService::start(2);
    let m = graphs::rmat(1 << 9, 8 << 9, 0.57, 0.19, 0.19, 77);
    let ks = [3usize, 6, 9, 12];
    let batch = svc.submit_batch(m.clone(), SolveOptions::default(), &ks);
    assert_eq!(batch.len(), ks.len());
    let mut singles = Vec::new();
    for &k in &ks {
        let (_, t) = svc.submit(m.clone(), SolveOptions { k, ..Default::default() });
        singles.push(t);
    }
    for (((_, bt), st), &k) in batch.into_iter().zip(singles).zip(&ks) {
        let b = bt.wait();
        let s = st.wait();
        let (b, s) = (b.outcome.expect("batch member"), s.outcome.expect("single"));
        assert_eq!(b.k(), s.k(), "k={k}");
        for i in 0..b.k() {
            assert!(
                (b.eigenvalues[i] - s.eigenvalues[i]).abs() < 1e-9,
                "k={k} pair {i}: batch {} vs single {}",
                b.eigenvalues[i],
                s.eigenvalues[i]
            );
        }
    }
    let stats = svc.stats();
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.submitted, 2 * ks.len() as u64);
    assert_eq!(stats.completed, 2 * ks.len() as u64);
    assert_eq!(stats.failed, 0);
    assert!(stats.max_queued_s >= 0.0 && stats.total_solve_s >= 0.0);
    svc.shutdown();
}

#[test]
fn breakdown_path_returns_partial_solution() {
    // Rank-1 matrix (uniform outer product): the uniform Lanczos start is
    // exactly the eigenvector, so the recurrence breaks down after one
    // iteration; the solver must return the single exact pair rather than
    // erroring.
    let mut m = sparse::CooMatrix::new(64, 64);
    for i in 0..64 {
        for j in 0..64 {
            m.push(i, j, 1.0 / 64.0);
        }
    }
    let mut solver = Solver::new(SolveOptions { k: 8, ..Default::default() });
    let sol = solver.solve(&m).expect("solve");
    assert_eq!(sol.metrics.breakdown_at, Some(1));
    assert_eq!(sol.k(), 1);
    assert!((sol.eigenvalues[0] - 1.0).abs() < 1e-4, "{:?}", sol.eigenvalues);
}

#[test]
fn equal_rows_partition_matches_paper_default_solver() {
    // The paper partitions by equal rows; results must not depend on the
    // partition policy.
    let adj = graphs::rmat(1 << 8, 8 << 8, 0.6, 0.18, 0.18, 9);
    let mut a = Solver::new(SolveOptions { partition: PartitionPolicy::EqualRows, ..Default::default() });
    let mut b = Solver::new(SolveOptions { partition: PartitionPolicy::BalancedNnz, ..Default::default() });
    let sa = a.solve(&adj).unwrap();
    let sb = b.solve(&adj).unwrap();
    for i in 0..sa.k().min(sb.k()) {
        assert!((sa.eigenvalues[i] - sb.eigenvalues[i]).abs() < 1e-6);
    }
}

#[test]
fn runtime_load_missing_artifact_errors_cleanly() {
    use topk_eigen::runtime::Runtime;
    let rt = Runtime::cpu().expect("client");
    let err = match rt.load("definitely_missing.hlo.txt") {
        Ok(_) => panic!("missing artifact must not load"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("definitely_missing"), "error should name the artifact: {msg}");
}

#[test]
fn pjrt_spmv_rejects_oversized_matrix() {
    use std::sync::Arc;
    use topk_eigen::runtime::{PjrtSpmv, Runtime};
    // 1M rows exceeds every compiled variant: constructor must error, not
    // panic, so the coordinator can fall back to the native engine.
    let mut m = sparse::CooMatrix::new(1 << 20, 1 << 20);
    m.push(0, 0, 1.0);
    let rt = Arc::new(Runtime::cpu().expect("client"));
    let err = match PjrtSpmv::new(rt, &m) {
        Ok(_) => panic!("oversized matrix must not load"),
        Err(e) => e,
    };
    assert!(format!("{err}").contains("no SpMV artifact"), "{err}");
}

#[test]
fn reorth_every_zero_behaves_as_none() {
    // EveryN(0) must not divide by zero; it degrades to no reorth.
    let adj = graphs::erdos_renyi(128, 1024, 3);
    let mut a = Solver::new(SolveOptions { reorth: ReorthPolicy::EveryN(0), k: 4, ..Default::default() });
    let mut b = Solver::new(SolveOptions { reorth: ReorthPolicy::None, k: 4, ..Default::default() });
    let sa = a.solve(&adj).unwrap();
    let sb = b.solve(&adj).unwrap();
    assert_eq!(sa.eigenvalues, sb.eigenvalues);
}

#[test]
fn solver_more_cus_than_rows_is_fine() {
    let mut m = sparse::CooMatrix::new(3, 3);
    m.push(0, 1, 1.0);
    m.push(1, 0, 1.0);
    m.push(2, 2, 0.5);
    let mut s = Solver::new(SolveOptions { k: 2, cus: 16, ..Default::default() });
    let sol = s.solve(&m).unwrap();
    assert!(sol.k() >= 1);
}

#[test]
fn cli_binary_catalog_and_model_run() {
    // Smoke the installed binary end-to-end (subprocess, like a user).
    let exe = env!("CARGO_BIN_EXE_topk-eigen");
    let out = std::process::Command::new(exe).arg("catalog").output().expect("run");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wiki-Talk") && text.contains("wb-edu"), "{text}");

    let out = std::process::Command::new(exe)
        .args(["solve", "WB-GO@2048", "--k", "4", "--quiet", "--verify"])
        .output()
        .expect("run");
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("accuracy:"), "{text}");

    let out = std::process::Command::new(exe)
        .args(["model", "IT@2048", "--k", "8"])
        .output()
        .expect("run");
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("SLR0"));
}

//! Out-of-core streaming integration: the file-backed packet datapath must
//! be a pure *storage* change — same eigenpairs, same tridiagonal, same
//! basis bits as the resident engine at every precision and shard count —
//! while pinning only O(buffers) bytes instead of O(nnz).
//!
//! Four properties:
//!
//! 1. **Bitwise solve equality** through the coordinator, 4 precisions ×
//!    shard counts {1, 3, 5, 8}: eigenvalue and eigenvector bits match the
//!    resident solve exactly.
//! 2. **Bitwise phase-1 equality** at the Lanczos layer: the `Tridiagonal`
//!    and every basis row agree bit-for-bit between a resident
//!    `ShardedSpmv` and its OOC twin.
//! 3. **Damage rejection**: a missing manifest, a truncated shard file, a
//!    flipped payload byte, and a precision mismatch all surface as typed
//!    errors naming what broke and where.
//! 4. **Residency bound** (counting allocator): opening a packet directory
//!    and warm-sweeping it allocates buffer-pool bytes, never matrix
//!    bytes — the registry's O(n)+buffer charging model is real.

#[global_allocator]
static ALLOC: topk_eigen::util::alloc::CountingAlloc = topk_eigen::util::alloc::CountingAlloc;

use std::path::Path;
use std::sync::Arc;
use topk_eigen::coordinator::{Solution, SolveOptions, Solver};
use topk_eigen::fixed::{Dataword, Precision, Q1_15, Q1_31, Q2_30};
use topk_eigen::graphs;
use topk_eigen::lanczos::{
    lanczos_typed_ws, LanczosOptions, LanczosResult, LanczosWorkspace, ReorthPolicy,
};
use topk_eigen::sparse::ooc::{scratch_dir, shard_path, OocShardSource};
use topk_eigen::sparse::{OocMatrix, PacketFileWriter, PartitionPolicy, ShardedSpmv};
use topk_eigen::util::alloc::thread_allocated_bytes;

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

/// Raw bit patterns of a solution: `==` on floats would accept `-0.0` for
/// `0.0`, which is weaker than the storage-change-only contract.
fn solution_bits(sol: &Solution) -> (Vec<u64>, Vec<Vec<u32>>) {
    (
        sol.eigenvalues.iter().map(|l| l.to_bits()).collect(),
        sol.eigenvectors.iter().map(|v| v.iter().map(|x| x.to_bits()).collect()).collect(),
    )
}

#[test]
fn ooc_solves_match_resident_solves_bitwise() {
    let g = graphs::rmat(1 << 10, 8 << 10, 0.57, 0.19, 0.19, 31);
    for precision in Precision::ALL {
        for cus in [1usize, 3, 5, 8] {
            let opts = SolveOptions { k: 6, precision, cus, ..Default::default() };
            let mut solver = Solver::new(opts.clone());
            let prep = solver.prepare(&g).expect("prepare resident");
            let sol_res = solver.solve_prepared(&prep).expect("resident solve");

            let dir = scratch_dir(&format!("stream-eq-{}-{cus}", precision.name()));
            prep.export_ooc(&dir, Some(2048)).expect("export packet files");
            let mut osolver = Solver::new(opts.clone());
            let oprep = osolver.prepare_ooc(&dir).expect("prepare ooc");
            assert!(oprep.is_ooc());
            assert_eq!(oprep.engine(), "native-ooc");
            assert_eq!((oprep.n(), oprep.nnz()), (prep.n(), prep.nnz()));
            let sol_ooc = osolver.solve_prepared(&oprep).expect("ooc solve");

            assert_eq!(
                solution_bits(&sol_res),
                solution_bits(&sol_ooc),
                "{} cus={cus}: OOC eigenpairs diverged from resident",
                precision.name()
            );
            assert_eq!(sol_res.frobenius_norm.to_bits(), sol_ooc.frobenius_norm.to_bits());
            assert!(sol_ooc.metrics.io_bytes_read > 0, "OOC solve reported no file reads");
            assert_eq!(sol_res.metrics.io_bytes_read, 0, "resident solve charged file reads");
            cleanup(&dir);
        }
    }
}

fn tridiag_matches<V: Dataword>() {
    let m = Arc::new(graphs::erdos_renyi(300, 2400, 13).to_csr().to_precision::<V>());
    let dir = scratch_dir(&format!("stream-tridiag-{}", V::NAME));
    let man = PacketFileWriter::new(&dir)
        .chunk_target_bytes(1024)
        .write_csr(&m, 1.0, 3, PartitionPolicy::BalancedNnz)
        .expect("write packet files");
    assert_eq!(man.nnz, m.nnz());

    let resident = ShardedSpmv::with_own_pool(Arc::clone(&m), 3, PartitionPolicy::BalancedNnz);
    let ooc = ShardedSpmv::with_own_pool_ooc(OocMatrix::<V>::open(&dir).expect("open"));
    let opts = LanczosOptions {
        k: 10,
        reorth: ReorthPolicy::EveryN(2),
        fused: true,
        ..Default::default()
    };
    let mut ws = LanczosWorkspace::new();
    let a: LanczosResult<V> = lanczos_typed_ws(&resident, &opts, &mut ws);
    let b: LanczosResult<V> = lanczos_typed_ws(&ooc, &opts, &mut ws);

    assert_eq!(a.tridiag, b.tridiag, "{}: tridiagonal diverged on the OOC engine", V::NAME);
    assert_eq!(a.breakdown_at, b.breakdown_at);
    let bits = |r: &[f32]| r.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    for i in 0..a.k() {
        assert_eq!(
            bits(&a.basis_row_f32(i)),
            bits(&b.basis_row_f32(i)),
            "{}: basis row {i} diverged",
            V::NAME
        );
    }
    cleanup(&dir);
}

#[test]
fn fused_lanczos_tridiagonal_is_identical_on_the_ooc_engine() {
    tridiag_matches::<f32>();
    tridiag_matches::<Q1_31>();
    tridiag_matches::<Q2_30>();
    tridiag_matches::<Q1_15>();
}

fn write_sample(dir: &Path) {
    let m = graphs::erdos_renyi(200, 1400, 7).to_csr();
    PacketFileWriter::new(dir)
        .chunk_target_bytes(512)
        .write_csr(&m, 2.0, 2, PartitionPolicy::BalancedNnz)
        .expect("write packet files");
}

#[test]
fn damaged_directories_are_rejected_with_located_errors() {
    // Missing manifest.
    let dir = scratch_dir("stream-errs");
    let err = format!("{:#}", OocMatrix::<f32>::open(&dir).unwrap_err());
    assert!(err.contains("manifest"), "missing-manifest error unhelpful: {err}");

    // Truncated shard payload: opening names the packet line where data
    // stops, without reading any chunk.
    write_sample(&dir);
    let shard0 = shard_path(&dir, 0);
    let len = std::fs::metadata(&shard0).expect("stat").len();
    let f = std::fs::OpenOptions::new().write(true).open(&shard0).expect("reopen");
    f.set_len(len - 64).expect("truncate");
    drop(f);
    let err = format!("{:#}", OocMatrix::<f32>::open(&dir).unwrap_err());
    assert!(err.contains("truncated at packet line"), "truncation error unhelpful: {err}");
    cleanup(&dir);

    // Flipped payload byte: geometry still opens, the checksum pass names
    // the corrupt chunk and its row/line window.
    write_sample(&dir);
    let mut bytes = std::fs::read(&shard0).expect("read shard");
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    std::fs::write(&shard0, &bytes).expect("write corrupted shard");
    let ooc = OocMatrix::<f32>::open(&dir).expect("geometry is still consistent");
    let err = format!("{:#}", ooc.verify().unwrap_err());
    assert!(err.contains("checksum mismatch"), "corruption error unhelpful: {err}");
    assert!(err.contains("packet lines"), "corruption error lacks line window: {err}");
    cleanup(&dir);

    // Precision mismatch: files written as f32, engine opened at Q1.15.
    write_sample(&dir);
    let err = format!("{:#}", OocMatrix::<Q1_15>::open(&dir).unwrap_err());
    assert!(err.contains("precision mismatch"), "precision error unhelpful: {err}");
    cleanup(&dir);
}

#[test]
fn ooc_residency_is_buffer_bounded_not_nnz_bounded() {
    // Large enough that streaming actually wins: ~60k entries of CSR
    // against a handful of 4 KiB double buffers.
    let g = graphs::mesh2d(128, 128, 0.9, 0.02, 5);
    let opts = SolveOptions { k: 6, cus: 2, ..Default::default() };
    let mut solver = Solver::new(opts.clone());
    let prep = solver.prepare(&g).expect("prepare resident");
    let dir = scratch_dir("stream-bytes");
    prep.export_ooc(&dir, Some(4096)).expect("export packet files");

    // Opening allocates the chunk-buffer pool and chunk tables — strictly
    // less than the resident CSR those buffers replace. The counting
    // allocator is thread-local and chunk reads run on the matrix's I/O
    // pool, so this thread's delta is exactly the pinned footprint.
    let before = thread_allocated_bytes();
    let ooc = OocMatrix::<f32>::open(&dir).expect("open");
    let open_bytes = (thread_allocated_bytes() - before) as usize;
    assert!(
        ooc.buffer_bytes() < prep.resident_bytes(),
        "buffer pool {} >= resident CSR {}",
        ooc.buffer_bytes(),
        prep.resident_bytes()
    );
    assert!(
        open_bytes < prep.resident_bytes(),
        "open() allocated {open_bytes} bytes, as much as the {} byte resident CSR",
        prep.resident_bytes()
    );

    // A warm sweep must not materialize the matrix on the consuming
    // thread: per-chunk prefetch bookkeeping only, well under even the
    // buffer pool.
    let mut warm = 0usize;
    ooc.for_each_entry(|_, _, _| warm += 1);
    let before = thread_allocated_bytes();
    let mut swept = 0usize;
    ooc.for_each_entry(|_, _, _| swept += 1);
    let sweep_bytes = (thread_allocated_bytes() - before) as usize;
    assert_eq!(swept, prep.nnz());
    assert_eq!(warm, swept);
    assert!(
        sweep_bytes < ooc.buffer_bytes(),
        "warm sweep allocated {sweep_bytes} bytes against a {} byte buffer pool",
        ooc.buffer_bytes()
    );
    assert!(ooc.prefetch_stalls() <= ooc.chunks_read());

    // The coordinator charges the same model: OOC residency strictly
    // below the resident engine it mirrors.
    let mut osolver = Solver::new(opts.clone());
    let oprep = osolver.prepare_ooc(&dir).expect("prepare ooc");
    assert!(
        oprep.resident_bytes() < prep.resident_bytes(),
        "OOC residency {} not below resident {}",
        oprep.resident_bytes(),
        prep.resident_bytes()
    );
    cleanup(&dir);
}

#[test]
fn abandoned_partial_sweeps_leave_the_stream_intact() {
    // Regression companion to the `OocShardSource` drop fix (the unit test
    // in `sparse/ooc.rs` pins the pool count): a source dropped mid-stream
    // always has a prefetch in flight whose buffer must return to the
    // pool. Through the public API: abandon shard streams at every depth,
    // repeatedly, and the matrix must keep producing the identical full
    // entry stream — no lost buffers, no torn state, no stuck I/O jobs.
    let m = graphs::erdos_renyi(1600, 9000, 23).to_csr();
    let dir = scratch_dir("stream-abandon");
    PacketFileWriter::new(&dir)
        .chunk_target_bytes(512)
        .write_csr(&m, 1.0, 3, PartitionPolicy::EqualRows)
        .expect("write packet files");
    let ooc = OocMatrix::<f32>::open(&dir).expect("open");
    assert!(
        ooc.chunk_count() > ooc.parts().len(),
        "fixture must have multiple chunks per shard to keep a prefetch in flight"
    );

    let mut reference: Vec<(u32, u32, u32)> = Vec::new();
    ooc.for_each_entry(|r, c, v| reference.push((r, c, v.to_bits())));
    assert_eq!(reference.len(), m.nnz());

    for round in 0..3 {
        for shard in 0..ooc.parts().len() {
            // Depths 0 (constructor's prefetch only) through "all but one".
            for consumed in 0..ooc.shard_chunks(shard).max(1) {
                let mut src = OocShardSource::new(ooc.clone(), shard);
                for _ in 0..consumed {
                    let _ = src.next_chunk();
                }
                drop(src);
            }
        }
        let mut got: Vec<(u32, u32, u32)> = Vec::new();
        ooc.for_each_entry(|r, c, v| got.push((r, c, v.to_bits())));
        assert_eq!(got, reference, "round {round}: stream changed after abandoned sweeps");
    }
    cleanup(&dir);
}

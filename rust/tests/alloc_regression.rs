//! Steady-state allocation regression for the fused Lanczos iteration and
//! the batched Top-K query sweep.
//!
//! The fused datapath must perform **zero heap allocations per iteration**
//! after warmup: all scratch lives in a reused `LanczosWorkspace`, the
//! basis is one flat arena allocation per solve, and the pool's scoped
//! dispatch publishes stack descriptors instead of boxing jobs. This test
//! registers the thread-local counting allocator from `util::alloc` and
//! pins the property by showing the per-solve allocation count does not
//! grow with the iteration count (so the per-iteration increment is zero),
//! and stays under a small per-solve constant.
//!
//! Counting is thread-local to the publishing thread; the Lanczos loop
//! owns every steady-state allocation site (pool workers only execute
//! borrowed closures), so this is the thread where a regression would
//! show up.

#[global_allocator]
static ALLOC: topk_eigen::util::alloc::CountingAlloc = topk_eigen::util::alloc::CountingAlloc;

use std::sync::Arc;
use topk_eigen::graphs;
use topk_eigen::lanczos::{block_lanczos_typed_ws, BlockLanczosResult};
use topk_eigen::lanczos::{lanczos_typed_ws, LanczosOptions, LanczosResult, LanczosWorkspace};
use topk_eigen::lanczos::{ReorthPolicy, ShardedSpmv};
use topk_eigen::sparse::{normalize_frobenius, PartitionPolicy};
use topk_eigen::util::alloc::thread_allocations;

/// Allocations attributed to this thread while running `f`, excluding the
/// cost of dropping its result (measured before the drop).
fn allocs_during<T>(f: impl FnOnce() -> T) -> u64 {
    let before = thread_allocations();
    let out = f();
    let during = thread_allocations() - before;
    drop(out);
    during
}

#[test]
fn fused_iterations_allocate_nothing_after_warmup() {
    let mut g = graphs::rmat(1 << 11, 8 << 11, 0.57, 0.19, 0.19, 9);
    normalize_frobenius(&mut g);
    let csr = Arc::new(g.to_csr());
    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), 4, PartitionPolicy::BalancedNnz);
    let opts = |k| LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), fused: true, ..Default::default() };

    let mut ws = LanczosWorkspace::new();
    // Warmup at the largest shape: grows the workspace buffers once.
    let _warm: LanczosResult = lanczos_typed_ws(&engine, &opts(24), &mut ws);

    // Per-solve allocations at three iteration counts. Each solve still
    // allocates a constant set (basis arena, alpha/beta vectors, the
    // result's tridiagonal) — but the count must NOT scale with k, which
    // is exactly the "zero allocations per iteration" property.
    let a6 = allocs_during(|| -> LanczosResult { lanczos_typed_ws(&engine, &opts(6), &mut ws) });
    let a12 = allocs_during(|| -> LanczosResult { lanczos_typed_ws(&engine, &opts(12), &mut ws) });
    let a24 = allocs_during(|| -> LanczosResult { lanczos_typed_ws(&engine, &opts(24), &mut ws) });
    assert_eq!(a6, a12, "allocation count grew with iteration count ({a6} -> {a12})");
    assert_eq!(a12, a24, "allocation count grew with iteration count ({a12} -> {a24})");
    // The constant itself stays small: one basis arena + the handful of
    // result vectors. A fat bound catches gross regressions (per-iteration
    // boxing would add dozens) without pinning implementation details.
    assert!(a24 <= 16, "per-solve allocation constant too large: {a24}");
}

#[test]
fn block_iterations_allocate_nothing_after_warmup() {
    // The block engine extends the same workspace: panels, per-shard
    // partial slots and the A/B block scratch all live in reused buffers,
    // so a warm block solve's allocation count is a small constant —
    // independent of the iteration count at every block width. (The
    // constant itself varies with b: the band result stores one diagonal
    // vector per off-diagonal distance.)
    let mut g = graphs::rmat(1 << 11, 8 << 11, 0.57, 0.19, 0.19, 9);
    normalize_frobenius(&mut g);
    let csr = Arc::new(g.to_csr());
    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), 4, PartitionPolicy::BalancedNnz);
    let opts = |k, b| LanczosOptions {
        k,
        block_size: b,
        reorth: ReorthPolicy::EveryN(2),
        fused: true,
        ..Default::default()
    };
    let mut ws = LanczosWorkspace::new();
    // Warmup at the largest shape: k = 24 at the widest block (b = 4)
    // grows every buffer once; smaller (k, b) combinations fit within it.
    let _warm: BlockLanczosResult = block_lanczos_typed_ws(&engine, &opts(24, 4), &mut ws);
    for b in [1usize, 2, 4] {
        let a8 = allocs_during(|| -> BlockLanczosResult { block_lanczos_typed_ws(&engine, &opts(8, b), &mut ws) });
        let a16 = allocs_during(|| -> BlockLanczosResult { block_lanczos_typed_ws(&engine, &opts(16, b), &mut ws) });
        let a24 = allocs_during(|| -> BlockLanczosResult { block_lanczos_typed_ws(&engine, &opts(24, b), &mut ws) });
        assert_eq!(a8, a16, "b={b}: allocation count grew with iteration count ({a8} -> {a16})");
        assert_eq!(a16, a24, "b={b}: allocation count grew with iteration count ({a16} -> {a24})");
        // Constant set per solve: basis arena, A/B coefficient vectors,
        // the band result's diagonals. Fat bound, same spirit as above.
        assert!(a24 <= 32, "b={b}: per-solve allocation constant too large: {a24}");
    }
}

#[test]
fn unfused_path_also_reuses_the_workspace() {
    // The serial reference shares the workspace plumbing; its per-solve
    // allocations must be k-independent too (reorth runs in place).
    let mut g = graphs::rmat(1 << 10, 8 << 10, 0.57, 0.19, 0.19, 17);
    normalize_frobenius(&mut g);
    let csr = Arc::new(g.to_csr());
    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), 4, PartitionPolicy::BalancedNnz);
    let opts = |k| LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), fused: false, ..Default::default() };
    let mut ws = LanczosWorkspace::new();
    let _warm: LanczosResult = lanczos_typed_ws(&engine, &opts(16), &mut ws);
    let a8 = allocs_during(|| -> LanczosResult { lanczos_typed_ws(&engine, &opts(8), &mut ws) });
    let a16 = allocs_during(|| -> LanczosResult { lanczos_typed_ws(&engine, &opts(16), &mut ws) });
    assert_eq!(a8, a16, "unfused per-solve allocations grew with k ({a8} -> {a16})");
}

#[test]
fn batched_topk_allocations_do_not_scale_with_matrix_size() {
    // The batched Top-K sweep must allocate a constant set per call —
    // query refs, per-(shard, query) heaps, the merged results — and
    // nothing per row chunk, so a warm call's allocation count is flat in
    // the matrix size. `cus = 1` routes the whole sweep through the
    // calling thread (single-task scopes run inline), so the thread-local
    // counter sees every allocation the batch path makes; a multi-shard
    // dispatch would split the count nondeterministically between the
    // caller and the pool workers.
    let (k, b) = (8usize, 4usize);
    let mut plain = Vec::new();
    let mut bounded = Vec::new();
    for n in [512usize, 1024, 2048] {
        let mut g = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 23);
        normalize_frobenius(&mut g);
        let csr = Arc::new(g.to_csr());
        let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), 1, PartitionPolicy::BalancedNnz);
        let xs: Vec<Vec<f32>> = (0..b)
            .map(|q| (0..n).map(|i| ((i * 37 + q * 101 + 5) % 97) as f32 / 97.0 - 0.5).collect())
            .collect();
        let row_l1 = engine.row_l1_norms();
        let _warm = engine.top_k_batch(&xs, k);
        plain.push(allocs_during(|| engine.top_k_batch(&xs, k)));
        bounded.push(allocs_during(|| engine.top_k_batch_with_bounds(&xs, k, &row_l1)));
    }
    assert_eq!(plain[0], plain[1], "batched sweep allocations grew with n: {plain:?}");
    assert_eq!(plain[1], plain[2], "batched sweep allocations grew with n: {plain:?}");
    assert_eq!(bounded[0], bounded[1], "bounded sweep allocations grew with n: {bounded:?}");
    assert_eq!(bounded[1], bounded[2], "bounded sweep allocations grew with n: {bounded:?}");
    // The constant itself stays small: a fat bound catches gross
    // regressions (per-chunk boxing would add hundreds) without pinning
    // the exact breakdown.
    assert!(plain[2] <= 64, "per-batch allocation constant too large: {}", plain[2]);
}

#[test]
fn counting_allocator_counts_this_thread_only() {
    // Sanity-check the harness itself: an allocation on this thread is
    // counted; a worker thread's allocation is attributed to the worker.
    use topk_eigen::util::alloc::thread_allocated_bytes;
    let before = thread_allocations();
    let v: Vec<u8> = Vec::with_capacity(4096);
    assert!(thread_allocations() > before, "own allocation must count");
    drop(v);
    // A worker's 16 MiB buffer must not be attributed to this thread —
    // spawning costs a few small allocations here, nowhere near 16 MiB.
    let bytes_before = thread_allocated_bytes();
    std::thread::spawn(|| {
        let v: Vec<u8> = Vec::with_capacity(16 << 20);
        std::hint::black_box(&v);
    })
    .join()
    .unwrap();
    let spawned_bytes = thread_allocated_bytes() - bytes_before;
    assert!(spawned_bytes < (16 << 20), "worker allocation leaked into this thread: {spawned_bytes}");
}

//! Property tests for the pool-parallel sharded SpMV engine: the sharded
//! result must equal the serial CSR kernel for every shard count and
//! partition policy, including matrices that leave tail shards empty.
//!
//! Each output row is accumulated by exactly one worker in the serial
//! element order, so equality here is *bitwise*, which is stricter than
//! the 1e-6 closeness the acceptance bar asks for; both are asserted so a
//! future reduction-order change would still have a meaningful bound.

use std::sync::Arc;
use topk_eigen::lanczos::Operator;
use topk_eigen::prop_assert;
use topk_eigen::sparse::{CooMatrix, PartitionPolicy, ShardedSpmv};
use topk_eigen::util::pool::ThreadPool;
use topk_eigen::util::prop::{forall, Gen};

const SHARD_COUNTS: [usize; 4] = [1, 3, 5, 8];
const POLICIES: [PartitionPolicy; 2] = [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz];

/// Random symmetric COO matrix (post-normalization value regime).
fn gen_sym_coo(g: &mut Gen) -> CooMatrix {
    let n = g.usize_in(4, 200).max(4);
    let edges = g.usize_in(n, 6 * n).max(4);
    let mut m = CooMatrix::new(n, n);
    for _ in 0..edges {
        let r = g.rng().range(0, n);
        let c = g.rng().range(0, n);
        let v = g.f64_in(-0.5, 0.5) as f32;
        m.push(r, c, v);
        if r != c {
            m.push(c, r, v);
        }
    }
    m.canonicalize();
    m
}

fn assert_sharded_matches_serial(g: &mut Gen, coo: &CooMatrix, x: &[f32]) -> bool {
    let csr = Arc::new(coo.to_csr());
    let serial = csr.spmv(x);
    let pool = Arc::new(ThreadPool::new(5));
    for shards in SHARD_COUNTS {
        for policy in POLICIES {
            let op = ShardedSpmv::new(Arc::clone(&csr), shards, policy, Arc::clone(&pool));
            prop_assert!(g, op.cus() == shards, "shard count {} != {shards}", op.cus());
            let mut y = vec![0.0f32; csr.nrows];
            op.apply(x, &mut y);
            for i in 0..y.len() {
                prop_assert!(
                    g,
                    (y[i] - serial[i]).abs() <= 1e-6,
                    "row {i} off by more than 1e-6 (shards={shards} policy={policy:?}): {} vs {}",
                    y[i],
                    serial[i]
                );
                prop_assert!(
                    g,
                    y[i].to_bits() == serial[i].to_bits(),
                    "row {i} not bitwise equal (shards={shards} policy={policy:?})"
                );
            }
        }
    }
    true
}

#[test]
fn prop_sharded_spmv_matches_serial_across_shards_and_policies() {
    forall("sharded SpMV == serial SpMV for shards in {1,3,5,8} x both policies", |g| {
        let coo = gen_sym_coo(g);
        let x = g.vec_f32(coo.ncols, -1.0, 1.0);
        assert_sharded_matches_serial(g, &coo, &x)
    });
}

#[test]
fn prop_sharded_spmv_handles_empty_tail_shards() {
    // Fewer rows than shards: the partitioner pads with empty tail ranges,
    // which must neither panic nor perturb the output.
    forall("sharded SpMV with more shards than rows", |g| {
        let n = g.usize_in(1, 7).max(1);
        let mut coo = CooMatrix::new(n, n);
        for r in 0..n {
            let v = g.f64_in(-0.5, 0.5) as f32;
            coo.push(r, r, v);
            let c = g.rng().range(0, n);
            if c != r {
                let w = g.f64_in(-0.5, 0.5) as f32;
                coo.push(r, c, w);
                coo.push(c, r, w);
            }
        }
        coo.canonicalize();
        let x = g.vec_f32(n, -1.0, 1.0);
        assert_sharded_matches_serial(g, &coo, &x)
    });
}

#[test]
fn prop_sharded_spmv_handles_skewed_mass() {
    // All non-zeros concentrated in the first rows: under BalancedNnz the
    // leading shards absorb everything and the tail goes empty.
    forall("sharded SpMV with all mass in the first row(s)", |g| {
        let n = g.usize_in(8, 120).max(8);
        let mut coo = CooMatrix::new(n, n);
        for c in 0..n {
            let v = g.f64_in(-0.5, 0.5) as f32;
            if v != 0.0 {
                coo.push(0, c, v);
                if c != 0 {
                    coo.push(c, 0, v);
                }
            }
        }
        coo.push(0, 0, 0.25);
        coo.canonicalize();
        let x = g.vec_f32(n, -1.0, 1.0);
        assert_sharded_matches_serial(g, &coo, &x)
    });
}

#[test]
fn sharded_rmat_and_mesh_match_serial_with_five_shards() {
    // The acceptance-bar configuration, deterministic: 5 shards (the
    // paper's CU count) on an RMAT and a mesh graph, both policies.
    use topk_eigen::graphs;
    for coo in [
        graphs::rmat(1 << 10, 8 << 10, 0.57, 0.19, 0.19, 11),
        graphs::mesh2d(32, 32, 0.9, 0.01, 4),
    ] {
        let csr = Arc::new(coo.to_csr());
        let x: Vec<f32> = (0..csr.nrows).map(|i| ((i * 131) % 17) as f32 * 0.05 - 0.4).collect();
        let serial = csr.spmv(&x);
        for policy in POLICIES {
            let op = ShardedSpmv::with_own_pool(Arc::clone(&csr), 5, policy);
            let mut y = vec![0.0f32; csr.nrows];
            op.apply(&x, &mut y);
            for i in 0..y.len() {
                assert!(
                    (y[i] - serial[i]).abs() <= 1e-6,
                    "row {i} ({policy:?}): {} vs {}",
                    y[i],
                    serial[i]
                );
            }
        }
    }
}

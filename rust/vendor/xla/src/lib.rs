//! API stub of the `xla-rs` PJRT bindings.
//!
//! The `topk-eigen` `pjrt` feature compiles `src/runtime/{spmv,jacobi}.rs`
//! against this crate's signatures. The stub keeps the feature buildable in
//! hermetic environments with no XLA native toolchain: constructors that
//! need only host state succeed, while anything that would compile or
//! execute an HLO module returns an [`Error`] explaining that the real
//! bindings are not vendored. To actually execute AOT artifacts, point the
//! `xla` path dependency in `rust/Cargo.toml` at real `xla-rs` bindings —
//! the API surface here is a strict subset of theirs.

use std::fmt;
use std::path::Path;

/// Error type for every fallible stub operation.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub-wide `Result` alias.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT native bindings are not vendored in this build \
         (the `xla` path dependency is an API stub; point it at real \
         xla-rs bindings to execute artifacts)"
    ))
}

/// Marker for element types transferable between host slices and device
/// buffers/literals.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

/// A host-side literal value (tensor or tuple).
pub struct Literal(());

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Build a rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal(())
    }

    /// Copy the literal out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Destructure a 1-tuple literal into its single element.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    /// Read the first element of the literal.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        Err(unavailable("Literal::get_first_element"))
    }
}

/// A parsed HLO module (text format).
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file. The stub validates the path exists (so
    /// missing-artifact errors stay actionable) but cannot parse content.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let p = path.as_ref();
        if p.is_file() {
            Ok(Self(()))
        } else {
            Err(Error(format!("HLO text file not found: {}", p.display())))
        }
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self(())
    }
}

/// A PJRT client (CPU platform in this project).
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU PJRT client. Succeeds in the stub (holds no native
    /// state); compilation and buffer uploads are where the stub stops.
    pub fn cpu() -> Result<Self> {
        Ok(Self(()))
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    /// Upload a host slice as a device buffer.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// A compiled executable resident on a PJRT client.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with literal arguments; returns per-device output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    /// Execute with device-resident buffer arguments.
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Download the buffer into a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_cannot_compile() {
        let client = PjRtClient::cpu().expect("stub client");
        let proto_err = HloModuleProto::from_text_file("/definitely/missing.hlo.txt").unwrap_err();
        assert!(proto_err.to_string().contains("missing.hlo.txt"));
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        assert!(client.compile(&comp).is_err());
        assert!(client.buffer_from_host_buffer(&[1.0f32], &[1], None).is_err());
    }

    #[test]
    fn literals_construct_but_cannot_read_back() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(Literal::scalar(0.5f32).to_tuple1().is_err());
    }
}

//! Minimal, offline, API-compatible substitute for the `anyhow` crate.
//!
//! Vendored so the workspace builds hermetically with no registry access.
//! Covers the subset `topk-eigen` uses: [`Error`], [`Result`], the
//! [`anyhow!`], [`bail!`] and [`ensure!`] macros, and the [`Context`]
//! extension trait. Semantics mirror the real crate where it matters:
//!
//! * `{}` displays the outermost message only;
//! * `{:#}` displays the whole cause chain joined with `": "`;
//! * `{:?}` displays the message plus a `Caused by:` list;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`.

use std::fmt;

/// A dynamic error: an outermost message plus its chain of causes.
pub struct Error {
    /// `chain[0]` is the outermost (most recent) message; deeper causes
    /// follow in order.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an additional outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate over the cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Note: like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that would conflict with the blanket `From` below.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to fallible results.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn display_and_alternate_follow_anyhow() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = Error::from(io).context("loading artifact");
        assert_eq!(format!("{e}"), "loading artifact");
        assert_eq!(format!("{e:#}"), "loading artifact: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_build_messages() {
        let x = 3;
        assert_eq!(format!("{}", anyhow!("x = {x}")), "x = 3");
        assert_eq!(format!("{}", anyhow!("x = {}", x + 1)), "x = 4");
        assert!(fails(true).is_ok());
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
    }

    #[test]
    fn context_trait_wraps_results_and_options() {
        let r: Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }
}

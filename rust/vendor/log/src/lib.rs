//! Minimal, offline, API-compatible substitute for the `log` facade crate.
//!
//! Vendored so the workspace builds hermetically with no registry access.
//! Covers the subset `topk-eigen` uses: the [`Log`] trait, [`Level`] /
//! [`LevelFilter`], [`Record`] / [`Metadata`], [`set_logger`] /
//! [`set_max_level`] / [`max_level`], and the `error!`..`trace!` macros.
//! Like the real facade, logging is a no-op until a logger is installed
//! (see `topk_eigen::util::logging::init`).

use std::cmp::Ordering;
use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering as AtomicOrdering};
use std::sync::Mutex;

/// Verbosity level of a single log record (Error is most severe).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    /// Serious failures.
    Error = 1,
    /// Recoverable problems.
    Warn,
    /// High-level progress.
    Info,
    /// Developer diagnostics.
    Debug,
    /// Very fine-grained tracing.
    Trace,
}

/// Maximum-verbosity filter installed process-wide ([`set_max_level`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    /// Disable all logging.
    Off = 0,
    /// `Error` only.
    Error,
    /// `Warn` and up.
    Warn,
    /// `Info` and up.
    Info,
    /// `Debug` and up.
    Debug,
    /// Everything.
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        write!(f, "{s}")
    }
}

/// Metadata about a log record: its level and target module path.
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    /// The record's verbosity level.
    pub fn level(&self) -> Level {
        self.level
    }
    /// The record's target (module path by default).
    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the pre-formatted message arguments.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    /// The record's verbosity level.
    pub fn level(&self) -> Level {
        self.metadata.level
    }
    /// The record's target (module path by default).
    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
    /// The message as format arguments (displayable with `{}`).
    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
    /// The record's metadata.
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }
}

/// A logging backend; install one with [`set_logger`].
pub trait Log: Send + Sync {
    /// Fast pre-filter: would a record with this metadata be logged?
    fn enabled(&self, metadata: &Metadata) -> bool;
    /// Handle one record (only called when enabled).
    fn log(&self, record: &Record);
    /// Flush buffered output, if any.
    fn flush(&self);
}

/// Returned when [`set_logger`] is called more than once.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

static LOGGER: Mutex<Option<&'static dyn Log>> = Mutex::new(None);
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Install the process-wide logger. Fails if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    let mut slot = LOGGER.lock().unwrap_or_else(|p| p.into_inner());
    if slot.is_some() {
        return Err(SetLoggerError(()));
    }
    *slot = Some(logger);
    Ok(())
}

/// Set the process-wide maximum verbosity.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, AtomicOrdering::SeqCst);
}

/// The current process-wide maximum verbosity.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(AtomicOrdering::SeqCst) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Implementation detail of the logging macros — not public API.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    let logger = *LOGGER.lock().unwrap_or_else(|p| p.into_inner());
    if let Some(logger) = logger {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

/// Log at an explicit [`Level`].
#[macro_export]
macro_rules! log {
    (target: $target:expr, $lvl:expr, $($arg:tt)+) => {{
        let lvl = $lvl;
        if lvl <= $crate::max_level() {
            $crate::__private_log(lvl, $target, format_args!($($arg)+));
        }
    }};
    ($lvl:expr, $($arg:tt)+) => {
        $crate::log!(target: module_path!(), $lvl, $($arg)+)
    };
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

/// Log at [`Level::Trace`].
#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct CountingLogger;
    impl Log for CountingLogger {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            assert!(!record.target().is_empty());
            let _ = format!("{}", record.args());
            HITS.fetch_add(1, AtomicOrdering::SeqCst);
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_compare_against_filters() {
        assert!(Level::Error <= LevelFilter::Info);
        assert!(Level::Info <= LevelFilter::Info);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(!(Level::Trace <= LevelFilter::Off));
    }

    #[test]
    fn macros_respect_max_level_and_reach_logger() {
        static LOGGER_IMPL: CountingLogger = CountingLogger;
        let _ = set_logger(&LOGGER_IMPL);
        set_max_level(LevelFilter::Info);
        let before = HITS.load(AtomicOrdering::SeqCst);
        info!("hello {}", 1);
        debug!("filtered out {}", 2);
        let after = HITS.load(AtomicOrdering::SeqCst);
        assert_eq!(after - before, 1);
    }
}

//! Out-of-core vs in-memory Lanczos — the streaming-datapath acceptance
//! bench.
//!
//! For each storage format the harness prepares the same R-MAT graph twice:
//! resident (normalized + quantized CSR shards in RAM) and out-of-core
//! (the resident engine's exact bits exported to packet chunk files, then
//! streamed back through double-buffered prefetch). Both solves run the
//! identical fused Lanczos schedule; the bench asserts the eigenpairs are
//! **bitwise identical** — the OOC path must change where bytes live, never
//! what they compute — and that prefetch stalls stay strictly below chunks
//! read (I/O overlapped compute instead of serializing behind it).
//!
//! Reported per format: solve time, matrix bytes streamed per second on the
//! resident path, file bytes read per second on the OOC path, chunk and
//! stall counts.
//!
//! Defaults to the paper-scale shape n = 2^22 with 8n directed edges.
//! Override with:
//!
//! * `TOPK_OOC_N`       — problem size (CI quick mode runs 2^18)
//! * `TOPK_OOC_THREADS` — CU shards / pool workers
//! * `TOPK_BENCH_ITERS` — timed iterations per row
//!
//! Results append to `BENCH_ooc.json` (JSONL) unless `TOPK_BENCH_JSON`
//! points elsewhere; `scripts/check_bench_json.py <report> lanczos_ooc`
//! validates the rows in CI.

use topk_eigen::bench::{BenchConfig, BenchSuite};
use topk_eigen::coordinator::{Solution, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::graphs;
use topk_eigen::lanczos::LanczosWorkspace;
use topk_eigen::sparse::OocMatrix;

/// Pairs requested per solve.
const K: usize = 8;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Exact bit patterns of a solution — `f32`/`f64` equality would let
/// `-0.0 == 0.0` slip through the bitwise contract.
fn solution_bits(sol: &Solution) -> (Vec<u64>, Vec<Vec<u32>>) {
    (
        sol.eigenvalues.iter().map(|l| l.to_bits()).collect(),
        sol.eigenvectors.iter().map(|v| v.iter().map(|x| x.to_bits()).collect()).collect(),
    )
}

fn main() {
    if std::env::var("TOPK_BENCH_JSON").is_err() {
        std::env::set_var("TOPK_BENCH_JSON", "BENCH_ooc.json");
    }
    let n = env_usize("TOPK_OOC_N", 1 << 22);
    let cus = env_usize("TOPK_OOC_THREADS", 5);
    let mut suite = BenchSuite::new(
        "lanczos_ooc",
        &format!("out-of-core vs in-memory fused Lanczos, n={n} RMAT 8n edges, K={K}, {cus} shards"),
    );

    let g = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 23);
    println!("  graph: n={n} nnz={}", g.nnz());

    for precision in Precision::ALL {
        let opts = SolveOptions { k: K, precision, cus, ..Default::default() };

        // Resident engine, then its exact bits exported to packet files.
        let mut solver = Solver::new(opts.clone());
        let prep = solver.prepare(&g).expect("prepare resident");
        let dir = std::env::temp_dir().join(format!("topk-ooc-bench-{}-{n}-{}", precision.name(), std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let man = prep.export_ooc(&dir, None).expect("export packet files");
        let mut ooc_solver = Solver::new(opts.clone());
        let ooc_prep = ooc_solver.prepare_ooc(&dir).expect("prepare ooc");
        let chunks = topk_eigen::with_precision!(precision, V => {
            OocMatrix::<V>::open(&dir).expect("open for chunk count").chunk_count()
        });

        let mut ws = LanczosWorkspace::new();
        let cfg = BenchConfig::default();
        let name = precision.name().replace('.', "");

        let t_res = suite.bench(&format!("resident_{name}"), cfg, || {
            Solver::solve_detached(&prep, K, &opts, &mut ws, None).expect("resident solve")
        });
        let sol_res = Solver::solve_detached(&prep, K, &opts, &mut ws, None).expect("resident solve");
        let mr = &sol_res.metrics;
        suite.annotate(&[
            ("n", n as f64),
            ("nnz", man.nnz as f64),
            ("resident_bytes", prep.resident_bytes() as f64),
            ("bytes_streamed", mr.bytes_streamed as f64),
            ("bytes_per_s", mr.bytes_streamed as f64 / mr.lanczos_s.max(1e-12)),
        ]);

        let t_ooc = suite.bench(&format!("ooc_{name}"), cfg, || {
            Solver::solve_detached(&ooc_prep, K, &opts, &mut ws, None).expect("ooc solve")
        });
        let sol_ooc = Solver::solve_detached(&ooc_prep, K, &opts, &mut ws, None).expect("ooc solve");
        let mo = &sol_ooc.metrics;

        // The whole point of the datapath: moving the matrix to disk must
        // not move a single bit of the answer.
        assert_eq!(
            solution_bits(&sol_res),
            solution_bits(&sol_ooc),
            "{}: OOC solve diverged from the resident solve",
            precision.name()
        );
        assert!(mo.io_bytes_read > 0, "{}: OOC solve read no file bytes", precision.name());
        // Chunks read by this solve: every fused sweep streams the full
        // chunk table once.
        let chunks_read = (mo.matrix_passes * chunks) as u64;
        assert!(
            mo.prefetch_stalls < chunks_read,
            "{}: {} stalls on {} chunk reads — prefetch failed to overlap I/O with compute",
            precision.name(),
            mo.prefetch_stalls,
            chunks_read
        );

        suite.annotate(&[
            ("n", n as f64),
            ("nnz", man.nnz as f64),
            ("resident_bytes", ooc_prep.resident_bytes() as f64),
            ("io_bytes_read", mo.io_bytes_read as f64),
            ("bytes_per_s", mo.io_bytes_read as f64 / mo.lanczos_s.max(1e-12)),
            ("chunks_read", chunks_read as f64),
            ("prefetch_stalls", mo.prefetch_stalls as f64),
            ("bitwise_equal", 1.0),
            ("slowdown_vs_resident", t_ooc / t_res.max(1e-12)),
        ]);
        println!(
            "  {}: resident {:.1} ms, ooc {:.1} ms ({:.2}x), {:.1} MB read/solve, \
             {} stalls / {} chunk reads, buffers {:.1} KiB vs CSR {:.1} KiB",
            precision.name(),
            t_res * 1e3,
            t_ooc * 1e3,
            t_ooc / t_res.max(1e-12),
            mo.io_bytes_read as f64 / 1e6,
            mo.prefetch_stalls,
            chunks_read,
            ooc_prep.resident_bytes() as f64 / 1024.0,
            prep.resident_bytes() as f64 / 1024.0,
        );

        let _ = std::fs::remove_dir_all(&dir);
    }
    suite.finish();
}

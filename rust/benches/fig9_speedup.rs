//! Fig 9: speedup of the FPGA design over the ARPACK-class CPU baseline,
//! per graph and K, with the geomean (excluding HT) the paper headlines
//! as 6.22x.
//!
//! CPU time is *measured* (thick-restart Lanczos, SpMV on all host cores —
//! the paper's baseline is 80-thread ARPACK); FPGA time comes from the
//! U280 timing model fed with the measured systolic step count (DESIGN.md,
//! hardware-substitution table).

mod common;

use std::sync::Arc;
use std::time::Instant;
use topk_eigen::bench::BenchSuite;
use topk_eigen::fpga::FpgaTimingModel;
use topk_eigen::iram::{iram, IramOptions};
use topk_eigen::jacobi::{systolic_jacobi, TrigMode};
use topk_eigen::lanczos::{lanczos, LanczosOptions, ReorthPolicy, ShardedSpmv};
use topk_eigen::sparse::{partition_rows_balanced, PartitionPolicy};
use topk_eigen::util::pool::ThreadPool;
use topk_eigen::util::timer::geomean;

fn main() {
    let scale = common::bench_scale();
    let mut suite = BenchSuite::new("fig9", &format!("FPGA-vs-CPU speedup, Table II suite @1/{scale}"));
    let model = FpgaTimingModel::default();
    let pool = Arc::new(ThreadPool::with_default_parallelism());
    let mut speedups: Vec<(usize, String, f64)> = Vec::new();

    for (e, g) in common::suite(scale) {
        let csr = Arc::new(g.to_csr());
        for k in [8usize, 16, 24] {
            let label = format!("{}/K{k}", e.id);
            // Measured multi-core CPU baseline.
            let op = ShardedSpmv::new(Arc::clone(&csr), pool.size(), PartitionPolicy::BalancedNnz, Arc::clone(&pool));
            let t0 = Instant::now();
            let _ = iram(&op, &IramOptions { k, tol: 1e-6, ..Default::default() });
            let cpu_s = t0.elapsed().as_secs_f64();
            // Modeled FPGA time with measured systolic steps.
            let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::EqualRows);
            let lz =
                lanczos(csr.as_ref(), &LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), ..Default::default() });
            let (_, _, stats) = systolic_jacobi(&lz.tridiag.to_dense(), TrigMode::Taylor3, 1e-9, 100);
            let fpga = model.solve_time(csr.nrows, &shards, k, ReorthPolicy::EveryN(2), stats.steps);
            let speedup = cpu_s / fpga.total_s();
            suite.report(
                &label,
                &[
                    ("cpu_s", cpu_s),
                    ("fpga_s", fpga.total_s()),
                    ("speedup", speedup),
                    ("nnz", csr.nnz() as f64),
                ],
            );
            speedups.push((k, e.id.to_string(), speedup));
        }
    }
    for k in [8usize, 16, 24] {
        let v: Vec<f64> = speedups
            .iter()
            .filter(|(kk, id, _)| *kk == k && id != "HT")
            .map(|(_, _, s)| *s)
            .collect();
        suite.report(&format!("geomean/K{k} (excl HT)"), &[("speedup", geomean(&v))]);
    }
    let all: Vec<f64> = speedups.iter().filter(|(_, id, _)| id != "HT").map(|(_, _, s)| *s).collect();
    suite.report("geomean/all (excl HT)", &[("speedup", geomean(&all)), ("paper", 6.22)]);
    suite.finish();
}

//! Fused vs unfused Lanczos iteration — the tentpole perf comparison.
//!
//! Measures the full Lanczos phase (SpMV + vector recurrence + reorth)
//! through the sharded engine with the fused single-sweep datapath on and
//! off, at K ∈ {8, 32} with the paper's every-2 reorthogonalization.
//! Defaults to the acceptance shape: n = 2^16 RMAT with 16n edges on a
//! 5-worker CU pool (≥ 4 threads). Override with:
//!
//! * `TOPK_LANCZOS_N`       — problem size (e.g. 16384 for the CI quick mode)
//! * `TOPK_LANCZOS_THREADS` — CU shards / pool workers
//! * `TOPK_BENCH_ITERS`     — timed iterations per row
//!
//! Results append to `BENCH_lanczos.json` (JSONL) unless `TOPK_BENCH_JSON`
//! points elsewhere, seeding the bench trajectory; the `speedup_fused`
//! column is the unfused/fused wall-time ratio (≥ 1.25x expected at K=32
//! on a multi-threaded host).

use std::sync::Arc;
use topk_eigen::bench::{BenchConfig, BenchSuite};
use topk_eigen::graphs;
use topk_eigen::lanczos::{lanczos_typed_ws, LanczosOptions, LanczosResult, LanczosWorkspace};
use topk_eigen::lanczos::{ReorthPolicy, ShardedSpmv};
use topk_eigen::sparse::{normalize_frobenius, PartitionPolicy};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    // Default artifact path: keep the Lanczos perf trajectory accumulating
    // even when the caller sets no TOPK_BENCH_JSON.
    if std::env::var("TOPK_BENCH_JSON").is_err() {
        std::env::set_var("TOPK_BENCH_JSON", "BENCH_lanczos.json");
    }
    let n = env_usize("TOPK_LANCZOS_N", 1 << 16);
    let threads = env_usize("TOPK_LANCZOS_THREADS", 5);
    let mut suite = BenchSuite::new(
        "lanczos_fused",
        &format!("fused vs unfused Lanczos phase, n={n} RMAT 16n edges, reorth every-2, {threads} threads"),
    );
    let mut g = graphs::rmat(n, 16 * n, 0.57, 0.19, 0.19, 7);
    normalize_frobenius(&mut g);
    let csr = Arc::new(g.to_csr());
    let engine = ShardedSpmv::with_own_pool(Arc::clone(&csr), threads, PartitionPolicy::BalancedNnz);
    // The telemetry pre-run below doubles as the warmup for each row, so
    // the timed loop adds no extra warmup solves.
    let cfg = BenchConfig { warmup: 0, ..Default::default() };
    let mut ws = LanczosWorkspace::new();

    for k in [8usize, 32] {
        let mk = |fused| LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), fused, ..Default::default() };
        let unfused_opts = mk(false);
        let warm_unfused = lanczos_typed_ws::<f32, _>(&engine, &unfused_opts, &mut ws);
        let t_unfused = suite.bench(&format!("unfused/k{k}"), cfg, || -> LanczosResult {
            lanczos_typed_ws(&engine, &unfused_opts, &mut ws)
        });
        suite.annotate(&[
            ("n", n as f64),
            ("k", k as f64),
            ("threads", threads as f64),
            ("vector_passes", warm_unfused.vector_passes as f64),
        ]);
        let fused_opts = mk(true);
        let warm_fused = lanczos_typed_ws::<f32, _>(&engine, &fused_opts, &mut ws);
        let t_fused = suite.bench(&format!("fused/k{k}"), cfg, || -> LanczosResult {
            lanczos_typed_ws(&engine, &fused_opts, &mut ws)
        });
        suite.annotate(&[
            ("n", n as f64),
            ("k", k as f64),
            ("threads", threads as f64),
            ("vector_passes", warm_fused.vector_passes as f64),
            ("fused_sweeps", warm_fused.fused_sweeps as f64),
            ("speedup_fused", t_unfused / t_fused),
        ]);
        println!(
            "  k={k}: unfused {:.1} ms, fused {:.1} ms -> {:.2}x ({} -> {} vector passes)",
            t_unfused * 1e3,
            t_fused * 1e3,
            t_unfused / t_fused,
            warm_unfused.vector_passes,
            warm_fused.vector_passes,
        );
    }
    suite.finish();
}

//! §V-B: power efficiency. The paper reports 49x Perf/Watt vs the CPU
//! (24x including the FPGA host server), from meter readings of 38 W
//! (card), 40 W (host), ~300 W (CPU). We reproduce that arithmetic with
//! measured CPU times and modeled FPGA times per graph.

mod common;

use std::sync::Arc;
use std::time::Instant;
use topk_eigen::bench::BenchSuite;
use topk_eigen::fpga::{FpgaTimingModel, PowerModel};
use topk_eigen::iram::{iram, IramOptions};
use topk_eigen::lanczos::{ReorthPolicy, ShardedSpmv};
use topk_eigen::sparse::{partition_rows_balanced, PartitionPolicy};
use topk_eigen::util::pool::ThreadPool;
use topk_eigen::util::timer::geomean;

fn main() {
    let scale = common::bench_scale();
    let mut suite = BenchSuite::new("power", &format!("Perf/Watt vs CPU @1/{scale} (paper: 49x / 24x)"));
    let model = FpgaTimingModel::default();
    let power = PowerModel::default();
    let pool = Arc::new(ThreadPool::with_default_parallelism());
    let k = 16;
    let mut gains = Vec::new();
    let mut gains_host = Vec::new();
    for (e, g) in common::small_suite(scale, &["WB-GO", "FL", "PA", "ASIA", "WK", "WB"]) {
        let csr = Arc::new(g.to_csr());
        let op = ShardedSpmv::new(Arc::clone(&csr), pool.size(), PartitionPolicy::BalancedNnz, Arc::clone(&pool));
        let t0 = Instant::now();
        let _ = iram(&op, &IramOptions { k, tol: 1e-6, ..Default::default() });
        let cpu_s = t0.elapsed().as_secs_f64();
        let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::EqualRows);
        let fpga_s = model.solve_time(csr.nrows, &shards, k, ReorthPolicy::EveryN(2), (k - 1) * 7).total_s();
        let r = power.compare(fpga_s, cpu_s);
        gains.push(r.perf_per_watt_gain);
        gains_host.push(r.perf_per_watt_gain_with_host);
        suite.report(
            e.id,
            &[
                ("cpu_energy_j", r.cpu_energy_j),
                ("fpga_energy_j", r.fpga_energy_j),
                ("perf_per_watt", r.perf_per_watt_gain),
                ("with_host", r.perf_per_watt_gain_with_host),
            ],
        );
    }
    suite.report(
        "geomean",
        &[
            ("perf_per_watt", geomean(&gains)),
            ("with_host", geomean(&gains_host)),
            ("paper", 49.0),
            ("paper_with_host", 24.0),
        ],
    );
    suite.finish();
}

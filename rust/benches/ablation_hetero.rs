//! Future-work ablation (§VI): heterogeneous GPU+FPGA deployment vs the
//! shipped FPGA-only design and a GPU-only alternative, across the Table
//! II suite. Validates the paper's closing hypothesis: GPU bandwidth for
//! the memory-bound SpMV phase + the FPGA systolic array for the
//! compute-bound small-K Jacobi dominates both pure deployments.

mod common;

use topk_eigen::bench::BenchSuite;
use topk_eigen::fpga::{compare_deployments, FpgaTimingModel, GpuModel};
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::sparse::{partition_rows_balanced, PartitionPolicy};

fn main() {
    let scale = common::bench_scale();
    let k = 16;
    let mut suite = BenchSuite::new(
        "ablation_hetero",
        &format!("FPGA vs GPU+FPGA vs GPU deployments, K={k} @1/{scale} (modeled at published sizes)"),
    );
    let fpga = FpgaTimingModel::default();
    let gpu = GpuModel::default();
    // Model at the PUBLISHED graph sizes (the deployment question is about
    // the real data-center workload, not the scaled twins): use catalog
    // rows/nnz directly with balanced shards.
    for e in topk_eigen::graphs::catalog() {
        // Synthetic shard table at published nnz (balanced).
        let g = e.generate(scale); // topology for the shard shape
        let csr = g.to_csr();
        let parts = partition_rows_balanced(&csr, 5, PartitionPolicy::BalancedNnz);
        // Rescale shard nnz to the published size.
        let factor = e.nnz as f64 / csr.nnz().max(1) as f64;
        let shards: Vec<_> = parts
            .iter()
            .map(|p| topk_eigen::sparse::RowPartition {
                row_start: p.row_start,
                row_end: p.row_end,
                nnz: (p.nnz as f64 * factor) as usize,
            })
            .collect();
        let (f, h, gp) = compare_deployments(&fpga, &gpu, e.rows, &shards, k, ReorthPolicy::EveryN(2), (k - 1) * 7);
        suite.report(
            e.id,
            &[
                ("fpga_s", f.total_s()),
                ("hybrid_s", h.total_s()),
                ("gpu_s", gp.total_s()),
                ("hybrid_vs_fpga", f.total_s() / h.total_s()),
                ("hybrid_vs_gpu", gp.total_s() / h.total_s()),
            ],
        );
    }
    suite.finish();
}

//! SpMV microbenchmark: the L3 hot path in isolation.
//!
//! Measures the native CSR-stripe engine's scaling across CU worker counts
//! and partition policies, plus the PJRT artifact path when artifacts are
//! present (skipped with a notice otherwise). This is the §Perf workhorse.

mod common;

use std::sync::Arc;
use topk_eigen::bench::{BenchConfig, BenchSuite};
use topk_eigen::graphs;
use topk_eigen::lanczos::{Operator, ShardedSpmv};
use topk_eigen::runtime::{ArtifactRegistry, PjrtSpmv, Runtime};
use topk_eigen::sparse::PartitionPolicy;
use topk_eigen::util::pool::ThreadPool;

fn main() {
    let scale = common::bench_scale();
    let mut suite = BenchSuite::new("spmv_micro", &format!("SpMV engine scaling @1/{scale}"));
    let (_, g) = common::small_suite(scale, &["WB"]).pop().expect("graph");
    let csr = Arc::new(g.to_csr());
    let x: Vec<f32> = (0..csr.nrows).map(|i| ((i * 2654435761) % 1000) as f32 / 1000.0 - 0.5).collect();
    let mut y = vec![0.0f32; csr.nrows];
    let nnz = csr.nnz() as f64;
    let cfg = BenchConfig { warmup: 2, iters: 10 };

    // Single-threaded reference.
    let mean = suite.bench("serial", cfg, || csr.spmv_into(&x, &mut y, 0, csr.nrows));
    suite.annotate(&[
        ("gflops", 2.0 * nnz / mean / 1e9),
        ("gbps_csr", (nnz * 8.0 + csr.nrows as f64 * 8.0) / mean / 1e9),
    ]);
    let serial = mean;

    for cus in [1usize, 2, 4, 5, 8] {
        let pool = Arc::new(ThreadPool::new(cus));
        for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
            let op = ShardedSpmv::new(Arc::clone(&csr), cus, policy, Arc::clone(&pool));
            let mean = suite.bench(&format!("sharded/cu{cus}/{policy:?}"), cfg, || op.apply(&x, &mut y));
            suite.annotate(&[("speedup_vs_serial", serial / mean), ("gflops", 2.0 * nnz / mean / 1e9)]);
        }
    }

    // PJRT artifact path (requires `make artifacts`).
    let coo = csr.to_coo();
    if ArtifactRegistry::pick_spmv(coo.nrows, coo.nnz()).is_some() {
        match Runtime::cpu().map(Arc::new).and_then(|rt| PjrtSpmv::new(rt, &coo)) {
            Ok(op) => {
                let mean = suite.bench("pjrt", cfg, || op.apply(&x, &mut y));
                suite.annotate(&[("speedup_vs_serial", serial / mean)]);
            }
            Err(e) => println!("pjrt path skipped: {e} (run `make artifacts`)"),
        }
    } else {
        println!("pjrt path skipped: no artifact variant fits n={} nnz={}", coo.nrows, coo.nnz());
    }

    // Acceptance-scale comparison: at n >= 2^16 the pool-parallel path must
    // not be slower than the serial kernel (override the size with
    // TOPK_SPMV_LARGE_N). Reported as `speedup_vs_serial` on the sharded
    // rows; >= 1.0 means the parallel path wins.
    let n_large: usize =
        std::env::var("TOPK_SPMV_LARGE_N").ok().and_then(|s| s.parse().ok()).unwrap_or(1 << 16);
    let g = graphs::rmat(n_large, 16 * n_large, 0.57, 0.19, 0.19, 7);
    let csr_large = Arc::new(g.to_csr());
    let nnz_large = csr_large.nnz() as f64;
    let x_large: Vec<f32> =
        (0..csr_large.nrows).map(|i| ((i * 2654435761) % 1000) as f32 / 1000.0 - 0.5).collect();
    let mut y_large = vec![0.0f32; csr_large.nrows];
    let serial_large = suite.bench(&format!("serial/n{n_large}"), cfg, || {
        csr_large.spmv_into(&x_large, &mut y_large, 0, csr_large.nrows)
    });
    suite.annotate(&[("gflops", 2.0 * nnz_large / serial_large / 1e9)]);
    let pool5 = Arc::new(ThreadPool::new(5));
    let mut slower = 0usize;
    for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
        let op = ShardedSpmv::new(Arc::clone(&csr_large), 5, policy, Arc::clone(&pool5));
        let mean = suite.bench(&format!("sharded/cu5/{policy:?}/n{n_large}"), cfg, || {
            op.apply(&x_large, &mut y_large)
        });
        suite.annotate(&[
            ("speedup_vs_serial", serial_large / mean),
            ("gflops", 2.0 * nnz_large / mean / 1e9),
            ("imbalance", op.imbalance()),
        ]);
        if mean > serial_large {
            slower += 1;
        }
    }
    if slower > 0 {
        println!(
            "WARNING: {slower} sharded configuration(s) slower than serial at n={n_large} \
             (expected >= 1.0x on a multi-core host)"
        );
    }
    suite.finish();
}

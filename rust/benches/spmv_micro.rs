//! SpMV microbenchmark: the L3 hot path in isolation.
//!
//! Measures the native CSR-stripe engine's scaling across CU worker counts
//! and partition policies, plus the PJRT artifact path when artifacts are
//! present (skipped with a notice otherwise). This is the §Perf workhorse.

mod common;

use std::sync::Arc;
use topk_eigen::bench::{BenchConfig, BenchSuite};
use topk_eigen::lanczos::{Operator, ShardedSpmv};
use topk_eigen::runtime::{ArtifactRegistry, PjrtSpmv, Runtime};
use topk_eigen::sparse::PartitionPolicy;
use topk_eigen::util::pool::ThreadPool;

fn main() {
    let scale = common::bench_scale();
    let mut suite = BenchSuite::new("spmv_micro", &format!("SpMV engine scaling @1/{scale}"));
    let (_, g) = common::small_suite(scale, &["WB"]).pop().expect("graph");
    let csr = Arc::new(g.to_csr());
    let x: Vec<f32> = (0..csr.nrows).map(|i| ((i * 2654435761) % 1000) as f32 / 1000.0 - 0.5).collect();
    let mut y = vec![0.0f32; csr.nrows];
    let nnz = csr.nnz() as f64;
    let cfg = BenchConfig { warmup: 2, iters: 10 };

    // Single-threaded reference.
    let mean = suite.bench("serial", cfg, || csr.spmv_into(&x, &mut y, 0, csr.nrows));
    suite.annotate(&[("gflops", 2.0 * nnz / mean / 1e9), ("gbps_csr", (nnz * 8.0 + csr.nrows as f64 * 8.0) / mean / 1e9)]);
    let serial = mean;

    for cus in [1usize, 2, 4, 5, 8] {
        let pool = Arc::new(ThreadPool::new(cus));
        for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
            let op = ShardedSpmv::new(Arc::clone(&csr), cus, policy, Arc::clone(&pool));
            let mean = suite.bench(&format!("sharded/cu{cus}/{policy:?}"), cfg, || op.apply(&x, &mut y));
            suite.annotate(&[("speedup_vs_serial", serial / mean), ("gflops", 2.0 * nnz / mean / 1e9)]);
        }
    }

    // PJRT artifact path (requires `make artifacts`).
    let coo = csr.to_coo();
    if ArtifactRegistry::pick_spmv(coo.nrows, coo.nnz()).is_some() {
        match Runtime::cpu().map(Arc::new).and_then(|rt| PjrtSpmv::new(rt, &coo)) {
            Ok(op) => {
                let mean = suite.bench("pjrt", cfg, || op.apply(&x, &mut y));
                suite.annotate(&[("speedup_vs_serial", serial / mean)]);
            }
            Err(e) => println!("pjrt path skipped: {e} (run `make artifacts`)"),
        }
    } else {
        println!("pjrt path skipped: no artifact variant fits n={} nnz={}", coo.nrows, coo.nnz());
    }
    suite.finish();
}

//! Ablation A2 (§IV, §V-C): storage precision of the Lanczos datapath.
//!
//! The paper replaces float with fixed-point where the Frobenius
//! normalization bounds values into (-1, 1). With the typed storage
//! datapath this is a real accuracy-vs-bandwidth trade-off, not a rounding
//! pass: per format the ablation reports tridiagonal drift and Fig 11
//! accuracy *and* the bytes the datapath actually moves — value-array
//! bytes, entries per 512-bit line, and packets/bytes streamed across the
//! solve's SpMVs. Results land in `BENCH_precision.json` (JSONL, one suite
//! per line) unless `TOPK_BENCH_JSON` points elsewhere, so the perf
//! trajectory accumulates across PRs.

mod common;

use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::{verify, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::lanczos::{lanczos, LanczosOptions, ReorthPolicy};

fn main() {
    // Default artifact path: keep the precision trajectory accumulating
    // even when the caller sets no TOPK_BENCH_JSON.
    if std::env::var("TOPK_BENCH_JSON").is_err() {
        std::env::set_var("TOPK_BENCH_JSON", "BENCH_precision.json");
    }
    let scale = common::bench_scale();
    let k = 16;
    let mut suite = BenchSuite::new("ablation_precision", &format!("fixed-point formats, K={k} @1/{scale}"));
    for (e, g) in common::small_suite(scale, &["WB-GO", "IT"]) {
        let csr = g.to_csr();
        let reference = lanczos(&csr, &LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), ..Default::default() });
        for precision in Precision::ALL {
            let lz = lanczos(
                &csr,
                &LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), precision, ..Default::default() },
            );
            // Tridiagonal drift vs f32.
            let n_cmp = lz.tridiag.k().min(reference.tridiag.k());
            let drift = (0..n_cmp)
                .map(|i| (lz.tridiag.alpha[i] - reference.tridiag.alpha[i]).abs())
                .fold(0.0f64, f64::max);
            // End-to-end metrics through the typed engine.
            let mut solver = Solver::new(SolveOptions { k, precision, ..Default::default() });
            let sol = solver.solve(&g).expect("solve");
            let r = verify::verify(&g, &sol);
            let mt = &sol.metrics;
            suite.report(
                &format!("{}/{}", e.id, precision.name()),
                &[
                    ("alpha_drift_vs_f32", drift),
                    ("angle_deg", r.mean_angle_deg),
                    ("mean_residual", r.mean_residual),
                    // Storage datapath: these columns must *differ* between
                    // formats — that is the point of typed storage.
                    ("value_bytes", mt.value_bytes as f64),
                    ("basis_bytes", mt.basis_bytes as f64),
                    ("entries_per_line", mt.packet_capacity as f64),
                    ("packets_streamed", mt.packets_streamed as f64),
                    ("hbm_bytes_streamed", mt.bytes_streamed as f64),
                ],
            );
        }
    }
    suite.finish();
}

//! Ablation A2 (§IV, §V-C): arithmetic precision of the Lanczos datapath.
//!
//! The paper replaces float with fixed-point where the Frobenius
//! normalization bounds values into (-1, 1). This ablation quantifies the
//! accuracy cost across Q formats (f32 / Q1.31 / Q2.30 / Q1.15): tridiagonal
//! drift vs the f32 reference and end-to-end Fig 11 metrics.

mod common;

use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::{verify, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::lanczos::{lanczos, LanczosOptions, ReorthPolicy};

fn main() {
    let scale = common::bench_scale();
    let k = 16;
    let mut suite = BenchSuite::new("ablation_precision", &format!("fixed-point formats, K={k} @1/{scale}"));
    for (e, g) in common::small_suite(scale, &["WB-GO", "IT"]) {
        let csr = g.to_csr();
        let reference = lanczos(&csr, &LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), ..Default::default() });
        for precision in [Precision::Float32, Precision::FixedQ1_31, Precision::FixedQ2_30, Precision::FixedQ1_15] {
            let lz = lanczos(
                &csr,
                &LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), precision, ..Default::default() },
            );
            // Tridiagonal drift vs f32.
            let n_cmp = lz.tridiag.k().min(reference.tridiag.k());
            let drift = (0..n_cmp)
                .map(|i| (lz.tridiag.alpha[i] - reference.tridiag.alpha[i]).abs())
                .fold(0.0f64, f64::max);
            // End-to-end metrics.
            let mut solver = Solver::new(SolveOptions { k, precision, ..Default::default() });
            let sol = solver.solve(&g).expect("solve");
            let r = verify::verify(&g, &sol);
            suite.report(
                &format!("{}/{}", e.id, precision.name()),
                &[
                    ("alpha_drift_vs_f32", drift),
                    ("angle_deg", r.mean_angle_deg),
                    ("mean_residual", r.mean_residual),
                ],
            );
        }
    }
    suite.finish();
}

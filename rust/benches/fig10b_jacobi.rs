//! Fig 10b: speedup of the systolic-array Jacobi over an optimized CPU
//! implementation, for growing K.
//!
//! CPU side: measured cyclic Jacobi (exact trig, the paper's "optimized
//! C++ CPU implementation" role). FPGA side: constant-time steps at
//! 225 MHz with the *measured* step count of the systolic schedule. The
//! paper's claim is quadratic CPU growth vs near-flat FPGA time.

mod common;

use topk_eigen::bench::{BenchConfig, BenchSuite};
use topk_eigen::fpga::{FpgaTimingModel, U280};
use topk_eigen::jacobi::{cyclic_jacobi, systolic_jacobi, TrigMode};
use topk_eigen::linalg::Tridiagonal;
use topk_eigen::util::rng::Pcg64;

fn main() {
    let mut suite = BenchSuite::new("fig10b", "systolic-vs-CPU Jacobi for growing K");
    let model = FpgaTimingModel::default();
    let mut rng = Pcg64::new(99);
    for k in [4usize, 8, 12, 16, 20, 24, 32] {
        let t = Tridiagonal::new(
            (0..k).map(|_| rng.f64_range(-1.0, 1.0)).collect(),
            (0..k - 1).map(|_| rng.f64_range(-1.0, 1.0)).collect(),
        );
        let dense = t.to_dense();
        let cpu_s = {
            let mut s = BenchConfig::default();
            s.iters = s.iters.max(10);
            // measure inline to keep the row's metric columns together
            let iters = 100;
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                std::hint::black_box(cyclic_jacobi(&dense, TrigMode::Exact, 1e-10, 100));
            }
            t0.elapsed().as_secs_f64() / iters as f64
        };
        let (_, _, stats) = systolic_jacobi(&dense, TrigMode::Taylor3, 1e-9, 100);
        let fpga_s = model.jacobi_cycles(k, stats.steps) as f64 / U280::CLOCK_HZ;
        suite.report(
            &format!("K{k}"),
            &[
                ("cpu_us", cpu_s * 1e6),
                ("fpga_us", fpga_s * 1e6),
                ("speedup", cpu_s / fpga_s),
                ("sa_steps", stats.steps as f64),
                ("sa_sweeps", stats.sweeps as f64),
            ],
        );
    }
    suite.finish();
}

//! Block vs single-vector Lanczos — HBM bytes streamed per converged
//! Ritz pair, the tentpole metric of the block datapath.
//!
//! Both paths solve the same Top-K=8 problem through the coordinator on
//! the sharded engine. For each width the harness sweeps the fixed
//! schedule upward (8, 12, 16, ... basis columns) until all 8 Ritz pairs
//! pass the residual oracle `||M v - lambda v||_2 <= 5e-3 * |lambda_1|`
//! (checked against the CSR matrix outside the timed region), then times
//! one solve at the first converging schedule. The single-vector path
//! streams the matrix value array once per basis column; the block path
//! advances 4 columns per stream, so at comparable subspace sizes its
//! bytes-per-converged-pair figure drops ~4x. The bench gates the drop at
//! >= 2x (`bytes_drop_b4`), leaving headroom for the block space needing
//! somewhat more columns than the single-vector space.
//!
//! Defaults to the acceptance shape: n = 2^14 RMAT with 16n edges on a
//! 5-shard CU pool. Override with:
//!
//! * `TOPK_LANCZOS_N`       — problem size
//! * `TOPK_LANCZOS_THREADS` — CU shards / pool workers
//! * `TOPK_BENCH_ITERS`     — timed iterations per row
//!
//! Results append to `BENCH_block.json` (JSONL) unless `TOPK_BENCH_JSON`
//! points elsewhere; `scripts/check_bench_json.py <report> lanczos_block`
//! validates the rows in CI.

use std::sync::Arc;
use topk_eigen::bench::{BenchConfig, BenchSuite};
use topk_eigen::coordinator::{PreparedMatrix, Solution, SolveOptions, Solver};
use topk_eigen::graphs;
use topk_eigen::lanczos::{LanczosWorkspace, Operator, ReorthPolicy};
use topk_eigen::sparse::{normalize_frobenius, CsrMatrix};

/// Pairs requested — the acceptance shape's K.
const K: usize = 8;
/// Residual gate, relative to the leading Ritz value.
const TOL_REL: f64 = 5e-3;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Ritz pairs among the leading `K` whose true residual passes the gate.
/// The matrix here is the Frobenius-normalized input itself (the solve
/// ran with `skip_normalize`, so eigenvalues come back unscaled).
fn converged_pairs(csr: &CsrMatrix, sol: &Solution, y: &mut Vec<f32>) -> usize {
    let scale = sol.eigenvalues.first().map_or(0.0, |l| l.abs()).max(1e-30);
    let mut conv = 0;
    for (lam, v) in sol.pairs().take(K) {
        y.resize(v.len(), 0.0);
        csr.apply(v, y);
        let r2: f64 = v
            .iter()
            .zip(y.iter())
            .map(|(&vi, &yi)| {
                let d = f64::from(yi) - lam * f64::from(vi);
                d * d
            })
            .sum();
        if r2.sqrt() <= TOL_REL * scale {
            conv += 1;
        }
    }
    conv
}

/// One fixed-schedule solve: `cols` basis columns at block width `b`
/// (`cols` matrix passes at b=1, `cols / b` on the block path).
fn solve_at(prep: &PreparedMatrix, base: &SolveOptions, cols: usize, b: usize, ws: &mut LanczosWorkspace) -> Solution {
    let opts = SolveOptions { k: cols, block_size: b, ..base.clone() };
    Solver::solve_detached(prep, cols, &opts, ws, None).expect("solve")
}

/// Smallest column budget (multiple of 4, so the block path runs whole
/// panels) whose top-K all pass the residual gate; best-converged
/// schedule at the cap if the gate is never fully met.
fn find_schedule(
    prep: &PreparedMatrix,
    base: &SolveOptions,
    csr: &CsrMatrix,
    max_cols: usize,
    b: usize,
    ws: &mut LanczosWorkspace,
    y: &mut Vec<f32>,
) -> (usize, Solution, usize) {
    let mut best: Option<(usize, Solution, usize)> = None;
    let mut cols = K;
    while cols <= max_cols {
        let sol = solve_at(prep, base, cols, b, ws);
        let conv = converged_pairs(csr, &sol, y);
        let done = conv >= K;
        if best.as_ref().map_or(true, |(_, _, c)| conv > *c) {
            best = Some((cols, sol, conv));
        }
        if done {
            break;
        }
        cols += 4;
    }
    best.expect("at least one schedule ran")
}

#[allow(clippy::too_many_arguments)]
fn report(
    suite: &mut BenchSuite,
    prep: &PreparedMatrix,
    base: &SolveOptions,
    csr: &CsrMatrix,
    shape: (usize, usize, usize),
    b: usize,
    ws: &mut LanczosWorkspace,
    y: &mut Vec<f32>,
) -> (f64, f64) {
    let (n, threads, max_cols) = shape;
    let (cols, sol, conv) = find_schedule(prep, base, csr, max_cols, b, ws, y);
    // The sweep above doubles as warmup; time the converged schedule.
    let cfg = BenchConfig { warmup: 0, ..Default::default() };
    let t = suite.bench(&format!("block_b{b}"), cfg, || solve_at(prep, base, cols, b, ws));
    let m = &sol.metrics;
    let bytes_per_pair = m.bytes_streamed as f64 / conv.max(1) as f64;
    suite.annotate(&[
        ("n", n as f64),
        ("k", K as f64),
        ("threads", threads as f64),
        ("block", b as f64),
        ("sched_cols", cols as f64),
        ("matrix_passes", m.matrix_passes as f64),
        ("spmv_count", m.spmv_count as f64),
        ("bytes_streamed", m.bytes_streamed as f64),
        ("converged", conv as f64),
        ("bytes_per_pair", bytes_per_pair),
    ]);
    println!(
        "  b={b}: {} cols -> {} matrix passes, {conv}/{K} pairs converged, \
         {:.2} MiB streamed ({:.3} MiB/pair), {:.1} ms/solve",
        cols,
        m.matrix_passes,
        m.bytes_streamed as f64 / (1 << 20) as f64,
        bytes_per_pair / (1 << 20) as f64,
        t * 1e3,
    );
    (bytes_per_pair, t)
}

fn main() {
    if std::env::var("TOPK_BENCH_JSON").is_err() {
        std::env::set_var("TOPK_BENCH_JSON", "BENCH_block.json");
    }
    let n = env_usize("TOPK_LANCZOS_N", 1 << 14);
    let threads = env_usize("TOPK_LANCZOS_THREADS", 5);
    let mut suite = BenchSuite::new(
        "lanczos_block",
        &format!("block vs single-vector Lanczos bytes/converged-pair, n={n} RMAT 16n edges, K={K}, {threads} shards"),
    );

    let mut g = graphs::rmat(n, 16 * n, 0.57, 0.19, 0.19, 11);
    normalize_frobenius(&mut g);
    // Residual oracle over the same normalized matrix the solver streams.
    let csr = Arc::new(g.to_csr());
    let base = SolveOptions {
        k: K,
        reorth: ReorthPolicy::Every,
        cus: threads,
        skip_normalize: true,
        ..Default::default()
    };
    let mut solver = Solver::new(base.clone());
    let prep = solver.prepare(&g).expect("prepare");
    let mut ws = LanczosWorkspace::new();
    let mut y: Vec<f32> = Vec::new();
    let shape = (n, threads, 96.min(n / 2).max(K));

    let (bpp1, t1) = report(&mut suite, &prep, &base, &csr, shape, 1, &mut ws, &mut y);
    let (bpp4, t4) = report(&mut suite, &prep, &base, &csr, shape, 4, &mut ws, &mut y);
    let drop = bpp1 / bpp4;
    suite.annotate(&[("bytes_drop_b4", drop), ("speedup_b4", t1 / t4)]);
    println!("  matrix bytes per converged Ritz pair drop at b=4: {drop:.2}x");
    assert!(
        drop >= 2.0,
        "block datapath must at least halve matrix bytes per converged pair (got {drop:.2}x)"
    );
    suite.finish();
}

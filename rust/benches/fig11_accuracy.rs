//! Fig 11: accuracy of the approximate eigencomputation for increasing K —
//! eigenvector pairwise orthogonality (degrees; ideal 90) and
//! reconstruction error ||Mv - lambda v|| on the normalized operator,
//! with and without reorthogonalization-every-2, on the fixed-point
//! (Q1.31) Lanczos datapath exactly like the hardware.

mod common;

use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::{verify, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::lanczos::ReorthPolicy;

fn main() {
    let scale = common::bench_scale();
    let mut suite = BenchSuite::new("fig11", &format!("accuracy vs K and reorth policy @1/{scale}"));
    let graphs = common::small_suite(scale, &["WB-GO", "IT", "PA", "FL"]);
    for k in [8usize, 12, 16, 20, 24] {
        for policy in [ReorthPolicy::EveryN(2), ReorthPolicy::None] {
            let (mut angle, mut resid, mut max_resid) = (0.0, 0.0, 0.0f64);
            for (_, g) in &graphs {
                let mut solver = Solver::new(SolveOptions {
                    k,
                    reorth: policy,
                    precision: Precision::FixedQ1_31,
                    ..Default::default()
                });
                let sol = solver.solve(g).expect("solve");
                let r = verify::verify(g, &sol);
                angle += r.mean_angle_deg;
                resid += r.mean_residual;
                max_resid = max_resid.max(r.max_residual);
            }
            let n = graphs.len() as f64;
            suite.report(
                &format!("K{k}/{}", policy.name()),
                &[
                    ("angle_deg", angle / n),
                    ("mean_residual", resid / n),
                    ("max_residual", max_resid),
                ],
            );
        }
    }
    suite.report("paper-thresholds", &[("angle_deg", 89.9), ("mean_residual", 1e-3)]);
    suite.finish();
}

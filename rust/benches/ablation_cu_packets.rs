//! Ablation A3 (§IV-B): compute-unit count, packet width, and partition
//! policy in the FPGA model.
//!
//! Sweeps CUs 1..8 (the paper ships 5 — bounded by the 32-port AXI switch:
//! 5 CUs x (1 matrix + 5 replica channels) = 30), packet widths, and
//! EqualRows vs BalancedNnz partitioning on a skewed power-law graph
//! (where the paper's equal-rows scheme leaves bandwidth on the table).

mod common;

use topk_eigen::bench::BenchSuite;
use topk_eigen::fpga::{FpgaTimingModel, U280};
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::sparse::{imbalance, partition_rows_balanced, PartitionPolicy};

fn main() {
    let scale = common::bench_scale();
    let k = 16;
    let mut suite = BenchSuite::new("ablation_cu_packets", &format!("CU/packet/partition sweep, K={k} @1/{scale}"));
    let (_, g) = common::small_suite(scale, &["WB-TA"]).pop().expect("graph"); // most skewed
    let csr = g.to_csr();

    for cus in 1..=8usize {
        let model = FpgaTimingModel { cus, ..Default::default() };
        for policy in [PartitionPolicy::EqualRows, PartitionPolicy::BalancedNnz] {
            let shards = partition_rows_balanced(&csr, cus, policy);
            let t = model.solve_time(csr.nrows, &shards, k, ReorthPolicy::EveryN(2), (k - 1) * 7);
            let channels = cus * (1 + U280::VECTOR_REPLICAS);
            suite.report(
                &format!("cu{cus}/{policy:?}"),
                &[
                    ("total_s", t.total_s()),
                    ("spmv_s", t.spmv_s),
                    ("read_gbps", model.effective_read_gbps(&shards)),
                    ("imbalance", imbalance(&shards)),
                    ("axi_channels", channels as f64),
                    ("fits_switch", if channels <= U280::HBM_AXI_CHANNELS { 1.0 } else { 0.0 }),
                ],
            );
        }
    }
    // Packet-width sweep at the shipped 5-CU point.
    for width in [1usize, 3, 5, 10, 15] {
        let model = FpgaTimingModel { packet_nnz: width, ..Default::default() };
        let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::EqualRows);
        let t = model.solve_time(csr.nrows, &shards, k, ReorthPolicy::EveryN(2), (k - 1) * 7);
        suite.report(
            &format!("packet{width}"),
            &[("total_s", t.total_s()), ("spmv_s", t.spmv_s)],
        );
    }
    suite.finish();
}

//! Fig 10a: time to process a single matrix value vs graph size.
//!
//! The paper's claim: the FPGA's per-nnz time is flat across graphs
//! (bandwidth-bound streaming), while the CPU's is erratic (cache
//! behaviour, restart counts). Reported as ns/nnz for both.

mod common;

use std::sync::Arc;
use std::time::Instant;
use topk_eigen::bench::BenchSuite;
use topk_eigen::fpga::FpgaTimingModel;
use topk_eigen::iram::{iram, IramOptions};
use topk_eigen::lanczos::ShardedSpmv;
use topk_eigen::sparse::{partition_rows_balanced, PartitionPolicy};
use topk_eigen::util::pool::ThreadPool;

fn main() {
    let scale = common::bench_scale();
    let k = 16;
    let mut suite = BenchSuite::new("fig10a", &format!("per-nnz processing time, K={k}, suite @1/{scale}"));
    let model = FpgaTimingModel::default();
    let pool = Arc::new(ThreadPool::with_default_parallelism());
    let mut fpga_per_nnz = Vec::new();

    for (e, g) in common::suite(scale) {
        let csr = Arc::new(g.to_csr());
        let op = ShardedSpmv::new(Arc::clone(&csr), pool.size(), PartitionPolicy::BalancedNnz, Arc::clone(&pool));
        let t0 = Instant::now();
        let _ = iram(&op, &IramOptions { k, tol: 1e-6, ..Default::default() });
        let cpu_s = t0.elapsed().as_secs_f64();
        let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::EqualRows);
        let fpga = model
            .solve_time(csr.nrows, &shards, k, topk_eigen::lanczos::ReorthPolicy::EveryN(2), (k - 1) * 7)
            .total_s();
        let nnz = csr.nnz() as f64;
        fpga_per_nnz.push(fpga / nnz * 1e9);
        suite.report(
            e.id,
            &[
                ("nnz", nnz),
                ("cpu_ns_per_nnz", cpu_s / nnz * 1e9),
                ("fpga_ns_per_nnz", fpga / nnz * 1e9),
            ],
        );
    }
    // The flatness claim, quantified: max/min spread of the FPGA line.
    let (min, max) = fpga_per_nnz.iter().fold((f64::MAX, 0.0f64), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    suite.report("fpga-flatness", &[("max_over_min", max / min)]);
    suite.finish();
}

//! Ablation A1 (§III-A, §V-C): reorthogonalization policy.
//!
//! Measures the native solver's wall time and accuracy for reorth = none /
//! every-2 / every, quantifying the O(n K^2 / 2) overhead the paper halves
//! with the every-2 cadence, and the FPGA-model cost of the same choice.

mod common;

use topk_eigen::bench::{BenchConfig, BenchSuite};
use topk_eigen::coordinator::{verify, SolveOptions, Solver};
use topk_eigen::fpga::FpgaTimingModel;
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::sparse::{partition_rows_balanced, PartitionPolicy};

fn main() {
    let scale = common::bench_scale();
    let k = 24; // large K makes the reorth term visible
    let mut suite = BenchSuite::new("ablation_reorth", &format!("reorth policy cost/accuracy, K={k} @1/{scale}"));
    let model = FpgaTimingModel::default();
    for (e, g) in common::small_suite(scale, &["WB-GO", "RC"]) {
        let csr = g.to_csr();
        let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::EqualRows);
        for policy in [ReorthPolicy::None, ReorthPolicy::EveryN(2), ReorthPolicy::Every] {
            let mut last = None;
            let mean_s = suite.bench(
                &format!("{}/{}", e.id, policy.name()),
                BenchConfig::default(),
                || {
                    let mut solver = Solver::new(SolveOptions { k, reorth: policy, ..Default::default() });
                    last = Some(solver.solve(&g).expect("solve"));
                },
            );
            let sol = last.unwrap();
            let r = verify::verify(&g, &sol);
            let fpga = model.solve_time(csr.nrows, &shards, k, policy, (k - 1) * 7);
            suite.annotate(&[
                ("native_s", mean_s),
                ("fpga_model_s", fpga.total_s()),
                ("fpga_reorth_share", fpga.reorth_s / fpga.total_s()),
                ("angle_deg", r.mean_angle_deg),
                ("max_cross_dot", r.max_cross_dot),
                ("mean_residual", r.mean_residual),
            ]);
        }
    }
    suite.finish();
}

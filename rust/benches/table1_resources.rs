//! Table I: resource usage and clock frequency of the hardware design,
//! from the calibrated U280 resource model, plus the scaling claims of
//! §IV-C (quadratic in K; K=32 is the practical ceiling).

use topk_eigen::bench::BenchSuite;
use topk_eigen::fpga::{jacobi_core_resources, lanczos_core_resources, SlrBudget, U280};

fn main() {
    let mut suite = BenchSuite::new("table1", "U280 resource model (percent of one SLR)");
    let rows = [
        ("SLR0/Lanczos-5CU", lanczos_core_resources(5)),
        ("SLR1/Jacobi-K32", jacobi_core_resources(32)),
        (
            "SLR2/Jacobi-2xK16",
            jacobi_core_resources(16).plus(jacobi_core_resources(16)),
        ),
    ];
    for (name, u) in rows {
        let (lut, ff, bram, uram, dsp) = SlrBudget::utilization_pct(u);
        suite.report(
            name,
            &[
                ("lut_pct", lut),
                ("ff_pct", ff),
                ("bram_pct", bram),
                ("uram_pct", uram),
                ("dsp_pct", dsp),
                ("clock_mhz", U280::CLOCK_HZ / 1e6),
            ],
        );
    }
    // Paper row for comparison.
    suite.report("paper/SLR0", &[
        ("lut_pct", 42.0),
        ("ff_pct", 13.0),
        ("bram_pct", 15.0),
        ("uram_pct", 0.0),
        ("dsp_pct", 16.0),
    ]);
    suite.report("paper/SLR1", &[
        ("lut_pct", 40.0),
        ("ff_pct", 42.0),
        ("bram_pct", 0.0),
        ("uram_pct", 0.0),
        ("dsp_pct", 68.0),
    ]);
    suite.report("paper/SLR2", &[
        ("lut_pct", 15.0),
        ("ff_pct", 17.0),
        ("bram_pct", 0.0),
        ("uram_pct", 0.0),
        ("dsp_pct", 34.0),
    ]);
    // Scaling: DSP cost quadruples per K doubling; K=64 does not fit.
    for k in [4usize, 8, 16, 32, 64] {
        let u = jacobi_core_resources(k);
        suite.report(
            &format!("scaling/K{k}"),
            &[("dsp", u.dsp as f64), ("fits_slr", if SlrBudget::fits(u) { 1.0 } else { 0.0 })],
        );
    }
    suite.finish();
}

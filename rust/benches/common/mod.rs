//! Shared helpers for the paper-reproduction benches.
#![allow(dead_code)] // each bench binary uses a different subset

use topk_eigen::graphs::{self, CatalogEntry};
use topk_eigen::sparse::{normalize_frobenius, CooMatrix};

/// Suite scale divisor: `TOPK_BENCH_SCALE` (default 512 — fast enough for
/// CI-style runs; use 64 or lower for paper-shaped magnitudes).
pub fn bench_scale() -> usize {
    std::env::var("TOPK_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(512)
}

/// Generate the Frobenius-normalized synthetic twin for one catalog entry.
pub fn twin(e: &CatalogEntry, scale: usize) -> CooMatrix {
    let mut g = e.generate(scale);
    normalize_frobenius(&mut g);
    g
}

/// The full Table II suite at the bench scale.
pub fn suite(scale: usize) -> Vec<(CatalogEntry, CooMatrix)> {
    graphs::catalog().into_iter().map(|e| (e.clone(), twin(&e, scale))).collect()
}

/// A reduced suite for the more expensive benches.
pub fn small_suite(scale: usize, ids: &[&str]) -> Vec<(CatalogEntry, CooMatrix)> {
    graphs::catalog()
        .into_iter()
        .filter(|e| ids.contains(&e.id))
        .map(|e| {
            let g = twin(&e, scale);
            (e, g)
        })
        .collect()
}

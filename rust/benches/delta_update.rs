//! Delta-update bench: incremental vs full re-prep across dirty fractions,
//! and warm-kept vs cold re-solve SpMV counts after a small delta.
//!
//! Writes JSONL rows (suite `delta_update`) to `$TOPK_BENCH_JSON`
//! (CI: `BENCH_update.json`). Knobs: `TOPK_UPDATE_N` (matrix rows,
//! default 16384 = the acceptance-bar n=2^14), `TOPK_BENCH_ITERS`.
//!
//! Rows:
//! * `reprep_dirty_<f>` — wall time of `update` + incremental `prepared`
//!   refresh vs a from-scratch `register` + `prepared` of the mutated
//!   matrix, for dirty fractions {0.1%, 1%, 10%}, plus the per-shard
//!   rebuild telemetry. Also asserts the refreshed engine solves bitwise
//!   identically to the from-scratch one (the exactness acceptance).
//! * `warm_vs_cold_k<k>` — SpMV counts of a warm-kept adaptive re-solve
//!   after a 0.1%-dirty delta vs the same solve run cold.

use std::time::Instant;
use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::{MatrixRegistry, RegistryConfig, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::graphs;
use topk_eigen::lanczos::LanczosWorkspace;
use topk_eigen::sparse::{CooDelta, CooMatrix};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Symmetric value-perturbation delta touching ~`frac` of the rows,
/// confined to a leading row band so dirty rows cluster in few CU shards
/// (the localized-churn pattern evolving graphs exhibit).
fn banded_delta(canon: &CooMatrix, frac: f64) -> CooDelta {
    let band = ((canon.nrows as f64 * frac).ceil() as usize).clamp(1, canon.nrows);
    let mut d = CooDelta::new(canon.nrows, canon.ncols);
    for i in 0..canon.nnz() {
        let (r, c) = (canon.rows[i] as usize, canon.cols[i] as usize);
        // Both endpoints in the band: mirrored edits stay local too.
        if r <= c && c < band {
            d.upsert_sym(r, c, canon.vals[i] * 1.05 + 1e-5);
        }
    }
    d
}

fn main() {
    let n = env_usize("TOPK_UPDATE_N", 1 << 14);
    let iters = env_usize("TOPK_BENCH_ITERS", 3).max(1);
    let base = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 20240831);
    let mut canon = base.clone();
    canon.canonicalize();
    let opts = SolveOptions { k: 8, ..Default::default() };

    let mut suite = BenchSuite::new(
        "delta_update",
        &format!("incremental vs full re-prep + warm vs cold re-solve @ n={n} nnz={}", canon.nnz()),
    );

    // ---- Incremental vs full re-prep across dirty fractions -------------
    for &frac in &[0.001f64, 0.01, 0.1] {
        let delta = banded_delta(&canon, frac);
        let mut mutated = canon.clone();
        {
            let mut d = delta.clone();
            d.canonicalize();
            mutated.apply_delta(&d);
        }

        let (mut incr_s, mut full_s) = (0.0f64, 0.0f64);
        let (mut shards_rebuilt, mut shards_reused) = (0u64, 0u64);
        let mut exact = true;
        for _ in 0..iters {
            // Incremental: registered once, delta spliced in, stale engine
            // refreshed on the next prepared().
            let reg = MatrixRegistry::default();
            let h = reg.register(base.clone()).expect("register");
            let _ = reg.prepared(h, &opts).expect("initial prepare");
            let t0 = Instant::now();
            reg.update(h, delta.clone()).expect("update");
            let inc = reg.prepared(h, &opts).expect("incremental refresh");
            incr_s += t0.elapsed().as_secs_f64();
            let stats = reg.stats();
            shards_rebuilt = stats.shards_rebuilt;
            shards_reused = stats.shards_reused;

            // Full: from-scratch register + prepare of the mutated matrix
            // (raw entry order: pays canonicalization like a cold client).
            let reg2 = MatrixRegistry::default();
            let t1 = Instant::now();
            let h2 = reg2.register(mutated.clone()).expect("register mutated");
            let fresh = reg2.prepared(h2, &opts).expect("fresh prepare");
            full_s += t1.elapsed().as_secs_f64();

            // Exactness: identical engines up to solve output, bitwise.
            let mut ws = LanczosWorkspace::new();
            let a = Solver::solve_detached(&inc, 8, &opts, &mut ws, None).expect("solve inc");
            let b = Solver::solve_detached(&fresh, 8, &opts, &mut ws, None).expect("solve fresh");
            exact &= a.eigenvalues == b.eigenvalues && a.eigenvectors == b.eigenvectors;
        }
        assert!(exact, "incremental refresh must equal from-scratch prepare bitwise (frac={frac})");
        let (incr_s, full_s) = (incr_s / iters as f64, full_s / iters as f64);
        suite.report(
            &format!("reprep_dirty_{frac}"),
            &[
                ("incremental_s", incr_s),
                ("full_s", full_s),
                ("speedup_incremental", full_s / incr_s.max(1e-12)),
                ("shards_rebuilt", shards_rebuilt as f64),
                ("shards_reused", shards_reused as f64),
                ("exact", 1.0),
            ],
        );
    }

    // ---- Warm-kept vs cold re-solve after a small delta ------------------
    // Adaptive stopping (the SpMV-count currency): a warm seed carried
    // across a 0.1%-dirty generation bump converges in fewer iterations.
    for &k in &[1usize, 4, 8] {
        let aopts = SolveOptions { k, adaptive_tol: Some(1e-8), ..Default::default() };
        let reg = MatrixRegistry::new(RegistryConfig { warm_start: true, ..Default::default() });
        let h = reg.register(base.clone()).expect("register");
        let prep = reg.prepared(h, &aopts).expect("prepare");
        let mut ws = LanczosWorkspace::new();
        let first = Solver::solve_detached(&prep, k, &aopts, &mut ws, None).expect("first solve");
        reg.store_warm(h, k, Precision::Float32, &first.eigenvectors[0]);

        let rep = reg.update(h, banded_delta(&canon, 0.001)).expect("update");
        assert!(rep.warm_kept, "0.1% delta must keep the warm seed (rel {})", rep.rel_delta);
        let prep2 = reg.prepared(h, &aopts).expect("refresh");
        let v1 = reg.warm_v1(h, k, Precision::Float32);
        assert!(v1.is_some(), "warm seed retained across generations");
        let warm = Solver::solve_detached(&prep2, k, &aopts, &mut ws, v1).expect("warm solve");
        let cold = Solver::solve_detached(&prep2, k, &aopts, &mut ws, None).expect("cold solve");
        suite.report(
            &format!("warm_vs_cold_k{k}"),
            &[
                ("warm_spmv", warm.metrics.spmv_count as f64),
                ("cold_spmv", cold.metrics.spmv_count as f64),
                ("spmv_saved", (cold.metrics.spmv_count as f64) - (warm.metrics.spmv_count as f64)),
                ("warm_started", if warm.metrics.warm_started { 1.0 } else { 0.0 }),
            ],
        );
    }

    suite.finish();
}

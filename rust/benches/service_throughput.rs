//! Serving-path throughput: jobs/sec of the one-shot owned path vs the
//! same-matrix batch path vs the matrix-resident registry path, plus the
//! FIFO vs K-batched reconfiguration comparison on a mixed-K trace.
//!
//! Writes JSONL rows (suite `service_throughput`) to `$TOPK_BENCH_JSON`
//! (CI: `BENCH_service.json`). Knobs: `TOPK_SERVICE_N` (matrix rows,
//! default 4096), `TOPK_SERVICE_JOBS` (trace length, default 24),
//! `TOPK_SERVICE_REPLICAS` (workers, default 4).

use std::time::Instant;
use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::service::{EigenService, QueuePolicy, ServiceConfig};
use topk_eigen::coordinator::{RegistryConfig, SolveOptions};
use topk_eigen::graphs;
use topk_eigen::sparse::CooMatrix;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn opts_k(k: usize) -> SolveOptions {
    SolveOptions { k, ..Default::default() }
}

/// Drain a ticket list, panicking on any failed job (throughput numbers
/// over failed solves would be meaningless).
fn drain(tickets: Vec<(u64, topk_eigen::coordinator::service::Ticket)>) {
    for (id, t) in tickets {
        let r = t.wait();
        assert!(r.outcome.is_ok(), "job {id} failed: {:?}", r.outcome.err());
    }
}

fn main() {
    let n = env_usize("TOPK_SERVICE_N", 1 << 12);
    let jobs = env_usize("TOPK_SERVICE_JOBS", 24);
    let replicas = env_usize("TOPK_SERVICE_REPLICAS", 4);
    let ks = [4usize, 8, 16, 32];
    let matrix: CooMatrix = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 4242);
    let trace: Vec<usize> = (0..jobs).map(|i| ks[i % ks.len()]).collect();

    let mut suite = BenchSuite::new(
        "service_throughput",
        &format!("serving paths @ n={n} nnz={} jobs={jobs} replicas={replicas}", matrix.nnz()),
    );

    // ---- Path 1: one-shot owned jobs (full prepare per job) -------------
    {
        let svc = EigenService::start(replicas);
        let t0 = Instant::now();
        let tickets: Vec<_> = trace.iter().map(|&k| svc.submit(matrix.clone(), opts_k(k))).collect();
        drain(tickets);
        let wall = t0.elapsed().as_secs_f64();
        suite.report("single_job", &[("jobs_per_s", jobs as f64 / wall), ("wall_s", wall), ("prepares", jobs as f64)]);
        svc.shutdown();
    }

    // ---- Path 2: same-matrix batches (one prepare per batch item) -------
    {
        let svc = EigenService::start(replicas);
        let t0 = Instant::now();
        let mut tickets = Vec::new();
        for chunk in trace.chunks(ks.len()) {
            tickets.extend(svc.submit_batch(matrix.clone(), SolveOptions::default(), chunk));
        }
        drain(tickets);
        let wall = t0.elapsed().as_secs_f64();
        let batches = trace.chunks(ks.len()).count();
        suite.report("batch", &[("jobs_per_s", jobs as f64 / wall), ("wall_s", wall), ("prepares", batches as f64)]);
        svc.shutdown();
    }

    // ---- Path 3: matrix-resident registry (one prepare, period) ---------
    {
        let svc = EigenService::start(replicas);
        let t0 = Instant::now();
        let handle = svc.register(matrix.clone()).expect("register");
        let tickets: Vec<_> = trace.iter().map(|&k| svc.submit_handle(handle, opts_k(k))).collect();
        drain(tickets);
        let wall = t0.elapsed().as_secs_f64();
        let rstats = svc.registry().stats();
        assert_eq!(rstats.prepares, 1, "registry path must prepare exactly once");
        suite.report(
            "registry",
            &[
                ("jobs_per_s", jobs as f64 / wall),
                ("wall_s", wall),
                ("prepares", rstats.prepares as f64),
                ("engine_hits", rstats.engine_hits as f64),
            ],
        );
        svc.shutdown();
    }

    // ---- Path 3b: registry + warm starts on a repeating (handle, k) -----
    {
        let svc = EigenService::with_config(ServiceConfig {
            replicas,
            registry: RegistryConfig { warm_start: true, ..Default::default() },
            ..Default::default()
        });
        let t0 = Instant::now();
        let handle = svc.register(matrix.clone()).expect("register");
        let tickets: Vec<_> = trace.iter().map(|&k| svc.submit_handle(handle, opts_k(k))).collect();
        drain(tickets);
        let wall = t0.elapsed().as_secs_f64();
        let rstats = svc.registry().stats();
        suite.report(
            "registry_warm",
            &[("jobs_per_s", jobs as f64 / wall), ("wall_s", wall), ("warm_hits", rstats.warm_hits as f64)],
        );
        svc.shutdown();
    }

    // ---- K-aware dispatch: FIFO vs KBatched reconfigurations ------------
    // Deterministic: paused single-replica service, alternating-K trace
    // (FIFO's worst case), resumed once the whole trace is queued.
    let mixed: Vec<usize> = (0..jobs.max(8)).map(|i| if i % 2 == 0 { 8 } else { 24 }).collect();
    let mut reconfigs = Vec::new();
    for policy in [QueuePolicy::Fifo, QueuePolicy::KBatched] {
        let svc = EigenService::with_config(ServiceConfig {
            replicas: 1,
            policy,
            paused: true,
            ..Default::default()
        });
        let handle = svc.register(matrix.clone()).expect("register");
        let tickets: Vec<_> = mixed.iter().map(|&k| svc.submit_handle(handle, opts_k(k))).collect();
        let t0 = Instant::now();
        svc.resume();
        drain(tickets);
        let wall = t0.elapsed().as_secs_f64();
        reconfigs.push(svc.stats().reconfigs as f64);
        suite.report(
            &format!("mixed_k_{}", policy.name()),
            &[("reconfigs", svc.stats().reconfigs as f64), ("jobs_per_s", mixed.len() as f64 / wall), ("wall_s", wall)],
        );
        svc.shutdown();
    }
    assert!(
        reconfigs[1] < reconfigs[0],
        "KBatched must reduce reconfigurations vs FIFO ({} vs {})",
        reconfigs[1],
        reconfigs[0]
    );
    suite.report(
        "policy_summary",
        &[
            ("fifo_reconfigs", reconfigs[0]),
            ("kbatched_reconfigs", reconfigs[1]),
            ("reconfig_reduction", reconfigs[0] / reconfigs[1].max(1.0)),
        ],
    );

    suite.finish();
}

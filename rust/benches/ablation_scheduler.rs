//! Scheduling ablation: FIFO vs K-batched assignment over reconfigurable
//! Jacobi cores (§IV-C's per-SLR reconfiguration), on mixed multi-tenant
//! workloads. Reports makespan and reconfiguration counts; solve-time
//! estimates come from the FPGA timing model on catalog twins.

mod common;

use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::scheduler::{schedule, CoreFarm, JobSpec, Policy};
use topk_eigen::fpga::FpgaTimingModel;
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::sparse::{partition_rows_balanced, PartitionPolicy};
use topk_eigen::util::rng::Pcg64;

fn main() {
    let scale = common::bench_scale();
    let mut suite = BenchSuite::new("ablation_scheduler", &format!("FIFO vs K-batched core scheduling @1/{scale}"));
    let model = FpgaTimingModel::default();
    let farm = CoreFarm::default();
    let mut rng = Pcg64::new(7);

    // Estimate solve times for a few catalog twins at each K class.
    let graphs = common::small_suite(scale, &["WB-GO", "PA", "WK"]);
    let mut estimates: Vec<(usize, f64)> = Vec::new(); // (k, solve_s)
    for (_, g) in &graphs {
        let csr = g.to_csr();
        let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::EqualRows);
        for k in [4usize, 8, 16, 24, 32] {
            let t = model.solve_time(csr.nrows, &shards, k, ReorthPolicy::EveryN(2), (k - 1) * 7);
            estimates.push((k, t.total_s()));
        }
    }

    for jobs_n in [16usize, 64, 256] {
        let jobs: Vec<JobSpec> = (0..jobs_n)
            .map(|_| {
                let &(k, solve_s) = &estimates[rng.range(0, estimates.len())];
                JobSpec { k, solve_s }
            })
            .collect();
        let fifo = schedule(&farm, &jobs, Policy::Fifo).expect("fifo");
        let batched = schedule(&farm, &jobs, Policy::KBatched).expect("batched");
        suite.report(
            &format!("jobs{jobs_n}"),
            &[
                ("fifo_makespan_s", fifo.makespan_s),
                ("batched_makespan_s", batched.makespan_s),
                ("speedup", fifo.makespan_s / batched.makespan_s),
                ("fifo_reconfigs", fifo.reconfigs as f64),
                ("batched_reconfigs", batched.reconfigs as f64),
            ],
        );
    }
    suite.finish();
}

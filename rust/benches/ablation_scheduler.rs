//! Scheduling ablation: FIFO vs K-batched assignment over reconfigurable
//! Jacobi cores (§IV-C's per-SLR reconfiguration), on mixed multi-tenant
//! workloads. Reports makespan and reconfiguration counts; solve-time
//! estimates come from the FPGA timing model on catalog twins.
//!
//! Two sections, one policy type:
//!
//! 1. **Offline model** — `scheduler::schedule` simulates the core farm
//!    under `Policy::{Fifo, KBatched}` with timing-model estimates.
//! 2. **Live service** — the same mixed-K traces run through a real
//!    `EigenService` whose dispatch loop applies the *same*
//!    `QueuePolicy` type (and the same `select_next` rule the deployed
//!    workers run), reporting measured reconfiguration counts. Because
//!    the service re-exports the scheduler's `Policy` as its live
//!    `QueuePolicy`, the model and the deployment cannot drift apart.

mod common;

use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::scheduler::{schedule, CoreFarm, JobSpec, Policy};
use topk_eigen::coordinator::service::{select_next, EigenService, ServiceConfig};
use topk_eigen::coordinator::SolveOptions;
use topk_eigen::fpga::FpgaTimingModel;
use topk_eigen::graphs;
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::sparse::{partition_rows_balanced, PartitionPolicy};
use topk_eigen::util::rng::Pcg64;

fn main() {
    let scale = common::bench_scale();
    let mut suite = BenchSuite::new("ablation_scheduler", &format!("FIFO vs K-batched core scheduling @1/{scale}"));
    let model = FpgaTimingModel::default();
    let farm = CoreFarm::default();
    let mut rng = Pcg64::new(7);

    // Estimate solve times for a few catalog twins at each K class.
    let graphs_suite = common::small_suite(scale, &["WB-GO", "PA", "WK"]);
    let mut estimates: Vec<(usize, f64)> = Vec::new(); // (k, solve_s)
    for (_, g) in &graphs_suite {
        let csr = g.to_csr();
        let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::EqualRows);
        for k in [4usize, 8, 16, 24, 32] {
            let t = model.solve_time(csr.nrows, &shards, k, ReorthPolicy::EveryN(2), (k - 1) * 7);
            estimates.push((k, t.total_s()));
        }
    }

    // ---- Offline model: the §IV-C core-farm simulation -------------------
    for jobs_n in [16usize, 64, 256] {
        let jobs: Vec<JobSpec> = (0..jobs_n)
            .map(|_| {
                let &(k, solve_s) = &estimates[rng.range(0, estimates.len())];
                JobSpec { k, solve_s }
            })
            .collect();
        let fifo = schedule(&farm, &jobs, Policy::Fifo).expect("fifo");
        let batched = schedule(&farm, &jobs, Policy::KBatched).expect("batched");
        suite.report(
            &format!("model_jobs{jobs_n}"),
            &[
                ("fifo_makespan_s", fifo.makespan_s),
                ("batched_makespan_s", batched.makespan_s),
                ("speedup", fifo.makespan_s / batched.makespan_s),
                ("fifo_reconfigs", fifo.reconfigs as f64),
                ("batched_reconfigs", batched.reconfigs as f64),
            ],
        );
    }

    // ---- Live service: the deployed dispatch loop, same policy type ------
    // A paused single-replica service drains a mixed-K trace under each
    // policy; measured reconfigs come from ServiceStats, produced by the
    // same `select_next` rule exercised below.
    let trace: Vec<usize> = (0..24).map(|i| [4usize, 24, 8, 32][i % 4]).collect();
    for policy in [Policy::Fifo, Policy::KBatched] {
        let svc = EigenService::with_config(ServiceConfig {
            replicas: 1,
            policy,
            paused: true,
            ..Default::default()
        });
        let h = svc.register(graphs::mesh2d(12, 12, 0.9, 0.02, 3)).expect("register");
        let tickets: Vec<_> = trace
            .iter()
            .map(|&k| svc.submit_handle(h, SolveOptions { k, ..Default::default() }))
            .collect();
        let t0 = std::time::Instant::now();
        svc.resume();
        for (id, t) in tickets {
            assert!(t.wait().outcome.is_ok(), "live job {id} failed");
        }
        let wall = t0.elapsed().as_secs_f64();
        suite.report(
            &format!("live_{}", policy.name()),
            &[("reconfigs", svc.stats().reconfigs as f64), ("jobs_per_s", trace.len() as f64 / wall)],
        );
        svc.shutdown();
    }

    // Sanity-pin the dispatch rule itself (the function the workers run):
    // with core 8 loaded and core-8 work queued, KBatched keeps the core.
    let queue = [(8usize, 1.0), (32, 1.0), (8, 1.0)];
    assert_eq!(select_next(&queue, Some(8), Policy::KBatched), Some(0));
    assert_eq!(select_next(&queue, Some(32), Policy::KBatched), Some(1));
    assert_eq!(select_next(&queue, Some(8), Policy::Fifo), Some(0));

    suite.finish();
}

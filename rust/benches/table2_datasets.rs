//! Table II: the evaluation suite — published sizes next to the generated
//! synthetic twins (rows, nnz, sparsity, COO footprint), plus generation
//! time so dataset prep is accounted for.

mod common;

use topk_eigen::bench::{BenchConfig, BenchSuite};
use topk_eigen::graphs;

fn main() {
    let scale = common::bench_scale();
    let mut suite = BenchSuite::new("table2", &format!("dataset suite @1/{scale} (published vs generated)"));
    for e in graphs::catalog() {
        let mut generated = None;
        let mean_s = suite.bench(e.id, BenchConfig { warmup: 0, iters: 1 }, || {
            generated = Some(e.generate(scale));
        });
        let g = generated.unwrap();
        suite.annotate(&[
            ("pub_rows", e.rows as f64),
            ("pub_nnz", e.nnz as f64),
            ("pub_sparsity_pct", e.sparsity_pct()),
            ("pub_size_gb", e.size_gb()),
            ("gen_rows", g.nrows as f64),
            ("gen_nnz", g.nnz() as f64),
            ("gen_density", g.density()),
            ("gen_mb", g.size_bytes() as f64 / 1e6),
            ("gen_s", mean_s),
        ]);
    }
    suite.finish();
}

//! Streaming-query throughput on the resident-matrix datapath: Top-K SpMV
//! and PPR jobs/s plus latency percentiles (p50/p99 of queued + execute
//! time per ticket), under a pure query load and under a mixed eigen+query
//! trace sharing one queue and one engine.
//!
//! Internal correctness gates (the bench aborts rather than report numbers
//! over wrong answers): a 1-replica and an N-replica service must answer
//! the same query stream **bitwise identically**, every job must succeed,
//! and M PPR jobs against one resident matrix must trigger exactly one
//! column-sum build.
//!
//! Writes JSONL rows (suite `query_throughput`) to `$TOPK_BENCH_JSON`
//! (CI: `BENCH_query.json`). Knobs: `TOPK_QUERY_N` (matrix rows, default
//! 4096), `TOPK_QUERY_JOBS` (queries per section, default 64),
//! `TOPK_QUERY_REPLICAS` (workers, default 4), `TOPK_QUERY_K` (top-k,
//! default 16).

use std::time::Instant;
use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::service::EigenService;
use topk_eigen::coordinator::SolveOptions;
use topk_eigen::graphs;
use topk_eigen::sparse::{CooMatrix, PprOptions, TopKEntry};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Deterministic query vector in [-0.5, 0.5) — splitmix64 per element.
fn query_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    (0..n)
        .map(|_| {
            state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^= z >> 31;
            (z >> 40) as f32 / (1u64 << 24) as f32 - 0.5
        })
        .collect()
}

/// `p`-th percentile (0..=1) of an unsorted latency sample, in seconds.
fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    s[((s.len() as f64 - 1.0) * p).round() as usize]
}

fn main() {
    let n = env_usize("TOPK_QUERY_N", 1 << 12);
    let jobs = env_usize("TOPK_QUERY_JOBS", 64);
    let replicas = env_usize("TOPK_QUERY_REPLICAS", 4);
    let k = env_usize("TOPK_QUERY_K", 16);
    let matrix: CooMatrix = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 4242);

    let mut suite = BenchSuite::new(
        "query_throughput",
        &format!("streaming queries @ n={n} nnz={} jobs={jobs} replicas={replicas} k={k}", matrix.nnz()),
    );

    // ---- Gate: 1 vs N replicas answer bitwise identically ---------------
    {
        let checked = 4usize;
        let answers: Vec<Vec<Vec<TopKEntry>>> = [1usize, replicas.max(2)]
            .iter()
            .map(|&r| {
                let svc = EigenService::start(r);
                let handle = svc.register(matrix.clone()).expect("register");
                let tickets: Vec<_> = (0..checked as u64)
                    .map(|q| svc.submit_query(handle, query_vec(n, q), k, SolveOptions::default()).1)
                    .collect();
                let out = tickets
                    .into_iter()
                    .map(|t| t.wait().outcome.expect("query failed").entries)
                    .collect();
                svc.shutdown();
                out
            })
            .collect();
        assert_eq!(answers[0], answers[1], "1 vs {} replicas must answer bitwise identically", replicas.max(2));
        suite.report("replica_equivalence", &[("replicas", replicas.max(2) as f64), ("checked", checked as f64)]);
    }

    // ---- Pure Top-K query load ------------------------------------------
    {
        let svc = EigenService::start(replicas);
        let handle = svc.register(matrix.clone()).expect("register");
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..jobs as u64)
            .map(|q| svc.submit_query(handle, query_vec(n, q), k, SolveOptions::default()).1)
            .collect();
        let mut lat = Vec::with_capacity(jobs);
        for t in tickets {
            let r = t.wait();
            assert!(r.outcome.is_ok(), "query {} failed: {:?}", r.id, r.outcome.err());
            lat.push(r.queued_s + r.query_s);
        }
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(svc.registry().stats().prepares, 1, "queries must share one engine build");
        suite.report(
            "query_only",
            &[
                ("jobs_per_s", jobs as f64 / wall),
                ("wall_s", wall),
                ("p50_ms", percentile(&lat, 0.50) * 1e3),
                ("p99_ms", percentile(&lat, 0.99) * 1e3),
            ],
        );
        svc.shutdown();
    }

    // ---- Pure PPR load (one colsum build amortized across jobs) ---------
    {
        let ppr_jobs = (jobs / 8).max(4);
        let svc = EigenService::start(replicas);
        let handle = svc.register(matrix.clone()).expect("register");
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..ppr_jobs)
            .map(|i| {
                let ppr = PprOptions { source: (i * 131) % n, ..Default::default() };
                svc.submit_ppr(handle, ppr, SolveOptions::default()).1
            })
            .collect();
        let mut lat = Vec::with_capacity(ppr_jobs);
        for t in tickets {
            let r = t.wait();
            assert!(r.outcome.is_ok(), "ppr {} failed: {:?}", r.id, r.outcome.err());
            lat.push(r.queued_s + r.query_s);
        }
        let wall = t0.elapsed().as_secs_f64();
        let rstats = svc.registry().stats();
        assert_eq!(rstats.colsum_builds, 1, "one resident matrix -> one column-sum pass: {rstats:?}");
        suite.report(
            "ppr_only",
            &[
                ("jobs_per_s", ppr_jobs as f64 / wall),
                ("wall_s", wall),
                ("p50_ms", percentile(&lat, 0.50) * 1e3),
                ("p99_ms", percentile(&lat, 0.99) * 1e3),
                ("colsum_builds", rstats.colsum_builds as f64),
                ("colsum_hits", rstats.colsum_hits as f64),
            ],
        );
        svc.shutdown();
    }

    // ---- Mixed eigen + query load on one queue --------------------------
    // Solves and queries interleave in the same submission order a real
    // client mix would produce; query latency percentiles here show the
    // head-of-line cost of sharing the queue with eigensolves.
    {
        let solves = (jobs / 4).max(2);
        let svc = EigenService::start(replicas);
        let handle = svc.register(matrix.clone()).expect("register");
        let t0 = Instant::now();
        let mut solve_tickets = Vec::with_capacity(solves);
        let mut query_tickets = Vec::with_capacity(jobs);
        for i in 0..jobs.max(solves) {
            if i < solves {
                let opts = SolveOptions { k: if i % 2 == 0 { 8 } else { 16 }, ..Default::default() };
                solve_tickets.push(svc.submit_handle(handle, opts).1);
            }
            if i < jobs {
                query_tickets.push(svc.submit_query(handle, query_vec(n, 1000 + i as u64), k, SolveOptions::default()).1);
            }
        }
        let mut lat = Vec::with_capacity(jobs);
        for t in query_tickets {
            let r = t.wait();
            assert!(r.outcome.is_ok(), "mixed query {} failed: {:?}", r.id, r.outcome.err());
            lat.push(r.queued_s + r.query_s);
        }
        for t in solve_tickets {
            let r = t.wait();
            assert!(r.outcome.is_ok(), "mixed solve {} failed: {:?}", r.id, r.outcome.err());
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = svc.stats();
        assert_eq!(stats.queries as usize, jobs);
        suite.report(
            "mixed_eigen_query",
            &[
                ("jobs_per_s", (jobs + solves) as f64 / wall),
                ("wall_s", wall),
                ("solves", solves as f64),
                ("queries", jobs as f64),
                ("query_p50_ms", percentile(&lat, 0.50) * 1e3),
                ("query_p99_ms", percentile(&lat, 0.99) * 1e3),
            ],
        );
        svc.shutdown();
    }

    suite.finish();
}

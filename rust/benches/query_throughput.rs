//! Streaming-query throughput on the resident-matrix datapath: Top-K SpMV
//! and PPR jobs/s plus latency percentiles (p50/p99 of queued + execute
//! time per ticket), under a pure query load and under a mixed eigen+query
//! trace sharing one queue and one engine.
//!
//! Internal correctness gates (the bench aborts rather than report numbers
//! over wrong answers): a 1-replica and an N-replica service must answer
//! the same query stream **bitwise identically**, every job must succeed,
//! and M PPR jobs against one resident matrix must trigger exactly one
//! column-sum build.
//!
//! Three optimization rows ride on the same gates: `query_batched` proves
//! batched SpMM cuts matrix bytes per answered query >= 2x at batch 4
//! while staying bitwise equal to the single-query stream,
//! `query_early_exit` proves the bounded sweep skips cold shards on a
//! skewed-norm fixture without changing a bit, and `ppr_warm_restart`
//! counts the sweeps a cross-generation seed saves after a small delta.
//!
//! Writes JSONL rows (suite `query_throughput`) to `$TOPK_BENCH_JSON`
//! (CI: `BENCH_query.json`). Knobs: `TOPK_QUERY_N` (matrix rows, default
//! 4096), `TOPK_QUERY_JOBS` (queries per section, default 64),
//! `TOPK_QUERY_REPLICAS` (workers, default 4), `TOPK_QUERY_K` (top-k,
//! default 16).

use std::time::Instant;
use topk_eigen::bench::BenchSuite;
use topk_eigen::coordinator::service::{EigenService, ServiceConfig};
use topk_eigen::coordinator::{RegistryConfig, SolveOptions};
use topk_eigen::graphs;
use topk_eigen::lanczos::ShardedSpmv;
use topk_eigen::sparse::{CooDelta, CooMatrix, PartitionPolicy, PprOptions, TopKEntry};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Deterministic query vector in [-0.5, 0.5) — splitmix64 per element.
fn query_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    (0..n)
        .map(|_| {
            state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = state;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^= z >> 31;
            (z >> 40) as f32 / (1u64 << 24) as f32 - 0.5
        })
        .collect()
}

/// Structurally symmetric fixture with all heavy mass inside rows
/// `0..hot` (both endpoints of every 8.0-weight edge stay in the hot
/// block): `EqualRows` sharding isolates that block in shard 0, leaving
/// every other shard's score bound provably below the k-th score.
fn skewed_symmetric(n: usize, hot: usize) -> CooMatrix {
    let mut m = CooMatrix::new(n, n);
    for r in 0..hot {
        let c = (r + 1) % hot;
        m.push(r, c, 8.0);
        m.push(c, r, 8.0);
    }
    for r in hot..n {
        let c = hot + (r - hot + 1) % (n - hot);
        if c != r {
            m.push(r, c, 1e-4);
            m.push(c, r, 1e-4);
        }
    }
    m
}

/// `p`-th percentile (0..=1) of an unsorted latency sample, in seconds.
fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    s[((s.len() as f64 - 1.0) * p).round() as usize]
}

fn main() {
    let n = env_usize("TOPK_QUERY_N", 1 << 12);
    let jobs = env_usize("TOPK_QUERY_JOBS", 64);
    let replicas = env_usize("TOPK_QUERY_REPLICAS", 4);
    let k = env_usize("TOPK_QUERY_K", 16);
    let matrix: CooMatrix = graphs::rmat(n, 8 * n, 0.57, 0.19, 0.19, 4242);

    let mut suite = BenchSuite::new(
        "query_throughput",
        &format!("streaming queries @ n={n} nnz={} jobs={jobs} replicas={replicas} k={k}", matrix.nnz()),
    );

    // ---- Gate: 1 vs N replicas answer bitwise identically ---------------
    {
        let checked = 4usize;
        let answers: Vec<Vec<Vec<TopKEntry>>> = [1usize, replicas.max(2)]
            .iter()
            .map(|&r| {
                let svc = EigenService::start(r);
                let handle = svc.register(matrix.clone()).expect("register");
                let tickets: Vec<_> = (0..checked as u64)
                    .map(|q| svc.submit_query(handle, query_vec(n, q), k, SolveOptions::default()).1)
                    .collect();
                let out = tickets
                    .into_iter()
                    .map(|t| t.wait().outcome.expect("query failed").entries)
                    .collect();
                svc.shutdown();
                out
            })
            .collect();
        assert_eq!(answers[0], answers[1], "1 vs {} replicas must answer bitwise identically", replicas.max(2));
        suite.report("replica_equivalence", &[("replicas", replicas.max(2) as f64), ("checked", checked as f64)]);
    }

    // ---- Pure Top-K query load ------------------------------------------
    {
        let svc = EigenService::start(replicas);
        let handle = svc.register(matrix.clone()).expect("register");
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..jobs as u64)
            .map(|q| svc.submit_query(handle, query_vec(n, q), k, SolveOptions::default()).1)
            .collect();
        let mut lat = Vec::with_capacity(jobs);
        for t in tickets {
            let r = t.wait();
            assert!(r.outcome.is_ok(), "query {} failed: {:?}", r.id, r.outcome.err());
            lat.push(r.queued_s + r.query_s);
        }
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(svc.registry().stats().prepares, 1, "queries must share one engine build");
        suite.report(
            "query_only",
            &[
                ("jobs_per_s", jobs as f64 / wall),
                ("wall_s", wall),
                ("p50_ms", percentile(&lat, 0.50) * 1e3),
                ("p99_ms", percentile(&lat, 0.99) * 1e3),
            ],
        );
        svc.shutdown();
    }

    // ---- Batched multi-query SpMM: matrix bytes per answered query ------
    // One resident-matrix sweep answers a whole batch, so the HBM matrix
    // traffic per answered query drops ~b×. Gate: every batched answer is
    // bitwise equal to the b = 1 run of the same query stream.
    {
        let bjobs = jobs.max(8) / 8 * 8;
        let mut bytes_per_query = Vec::new();
        let mut rates = Vec::new();
        let mut baseline: Vec<Vec<TopKEntry>> = Vec::new();
        for &b in &[1usize, 4, 8] {
            // batch_cap = 1 disables scheduler-side coalescing so each row
            // isolates the explicit submit_query_batch chunk size.
            let svc = EigenService::with_config(ServiceConfig { replicas, batch_cap: 1, ..Default::default() });
            let handle = svc.register(matrix.clone()).expect("register");
            let t0 = Instant::now();
            let mut tickets = Vec::with_capacity(bjobs);
            if b == 1 {
                for q in 0..bjobs as u64 {
                    tickets.push(svc.submit_query(handle, query_vec(n, 5000 + q), k, SolveOptions::default()).1);
                }
            } else {
                let mut q = 0usize;
                while q < bjobs {
                    let xs: Vec<Vec<f32>> =
                        (q..q + b.min(bjobs - q)).map(|i| query_vec(n, 5000 + i as u64)).collect();
                    q += xs.len();
                    tickets.extend(
                        svc.submit_query_batch(handle, xs, k, SolveOptions::default()).into_iter().map(|(_, t)| t),
                    );
                }
            }
            let answers: Vec<Vec<TopKEntry>> = tickets
                .into_iter()
                .map(|t| t.wait().outcome.expect("batched query failed").entries)
                .collect();
            let wall = t0.elapsed().as_secs_f64();
            if b == 1 {
                baseline = answers;
            } else {
                assert_eq!(answers, baseline, "batch size {b} changed an answer");
            }
            let prep = svc.registry().prepared(handle, &SolveOptions::default()).expect("prepared");
            let engine = prep
                .operator()
                .as_any()
                .and_then(|a| a.downcast_ref::<ShardedSpmv<f32>>())
                .expect("native f32 engine");
            bytes_per_query.push(engine.bytes_streamed() as f64 / bjobs as f64);
            rates.push(bjobs as f64 / wall);
            svc.shutdown();
        }
        let drop_b4 = bytes_per_query[0] / bytes_per_query[1];
        let drop_b8 = bytes_per_query[0] / bytes_per_query[2];
        assert!(drop_b4 >= 2.0, "batch = 4 must at least halve matrix bytes per query: {bytes_per_query:?}");
        suite.report(
            "query_batched",
            &[
                ("jobs", bjobs as f64),
                ("bytes_per_query_b1", bytes_per_query[0]),
                ("bytes_per_query_b4", bytes_per_query[1]),
                ("bytes_per_query_b8", bytes_per_query[2]),
                ("bytes_drop_b4", drop_b4),
                ("bytes_drop_b8", drop_b8),
                ("jobs_per_s_b1", rates[0]),
                ("jobs_per_s_b4", rates[1]),
                ("jobs_per_s_b8", rates[2]),
            ],
        );
    }

    // ---- Early-exit shard pruning on a skewed-norm fixture --------------
    // Gate: the pruning path (cus = 8, EqualRows isolates the hot block in
    // shard 0) answers bitwise what a single-shard engine — which can never
    // prune — answers, while the service reports skipped shards.
    {
        let (skew_n, hot, checked) = (1024usize, 128usize, 8usize);
        let svc = EigenService::start(replicas);
        let handle = svc.register(skewed_symmetric(skew_n, hot)).expect("register skewed");
        let pruning = SolveOptions { cus: 8, partition: PartitionPolicy::EqualRows, ..Default::default() };
        let lone = SolveOptions { cus: 1, ..Default::default() };
        let t0 = Instant::now();
        for q in 0..checked as u64 {
            let x = query_vec(skew_n, 9000 + q);
            let a8 = svc.submit_query(handle, x.clone(), k, pruning.clone()).1.wait().outcome.expect("pruned query");
            let a1 = svc.submit_query(handle, x, k, lone.clone()).1.wait().outcome.expect("lone query");
            assert_eq!(a8.entries, a1.entries, "shard pruning changed query {q}");
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = svc.stats();
        assert!(stats.shards_skipped > 0, "skewed fixture must trigger early exit: {stats:?}");
        let rstats = svc.registry().stats();
        suite.report(
            "query_early_exit",
            &[
                ("queries", checked as f64),
                ("shards_skipped", stats.shards_skipped as f64),
                ("rowbound_builds", rstats.rowbound_builds as f64),
                ("rowbound_hits", rstats.rowbound_hits as f64),
                ("wall_s", wall),
            ],
        );
        svc.shutdown();
    }

    // ---- Pure PPR load (one colsum build amortized across jobs) ---------
    {
        let ppr_jobs = (jobs / 8).max(4);
        let svc = EigenService::start(replicas);
        let handle = svc.register(matrix.clone()).expect("register");
        let t0 = Instant::now();
        let tickets: Vec<_> = (0..ppr_jobs)
            .map(|i| {
                let ppr = PprOptions { source: (i * 131) % n, ..Default::default() };
                svc.submit_ppr(handle, ppr, SolveOptions::default()).1
            })
            .collect();
        let mut lat = Vec::with_capacity(ppr_jobs);
        for t in tickets {
            let r = t.wait();
            assert!(r.outcome.is_ok(), "ppr {} failed: {:?}", r.id, r.outcome.err());
            lat.push(r.queued_s + r.query_s);
        }
        let wall = t0.elapsed().as_secs_f64();
        let rstats = svc.registry().stats();
        assert_eq!(rstats.colsum_builds, 1, "one resident matrix -> one column-sum pass: {rstats:?}");
        suite.report(
            "ppr_only",
            &[
                ("jobs_per_s", ppr_jobs as f64 / wall),
                ("wall_s", wall),
                ("p50_ms", percentile(&lat, 0.50) * 1e3),
                ("p99_ms", percentile(&lat, 0.99) * 1e3),
                ("colsum_builds", rstats.colsum_builds as f64),
                ("colsum_hits", rstats.colsum_hits as f64),
            ],
        );
        svc.shutdown();
    }

    // ---- PPR warm restart across a generation bump ----------------------
    // A converged walk's fixed point seeds the same walk after a small
    // CooDelta update (opt-in `warm_start`); the damped iteration has a
    // unique fixed point, so the seed can only change how many sweeps the
    // walk needs, never where it lands.
    {
        let svc = EigenService::with_config(ServiceConfig {
            replicas,
            registry: RegistryConfig { warm_start: true, ..Default::default() },
            ..Default::default()
        });
        let handle = svc.register(matrix.clone()).expect("register");
        let popts = PprOptions { source: 17 % n, ..Default::default() };
        let cold =
            svc.submit_ppr(handle, popts.clone(), SolveOptions::default()).1.wait().outcome.expect("cold ppr");
        assert!(cold.ppr.converged, "cold walk must converge");
        assert!(!cold.ppr.warm_started);
        let mut canon = matrix.clone();
        canon.canonicalize();
        let mut delta = CooDelta::new(canon.nrows, canon.ncols);
        let (dr, dc) = (canon.rows[0] as usize, canon.cols[0] as usize);
        delta.upsert_sym(dr, dc, canon.vals[0] * 1.01);
        assert!(svc.submit_update(handle, delta).1.wait().outcome.is_ok(), "update failed");
        let warm =
            svc.submit_ppr(handle, popts, SolveOptions::default()).1.wait().outcome.expect("warm ppr");
        assert!(warm.ppr.warm_started, "seed must survive a small generation bump");
        assert!(warm.ppr.converged, "warm walk must converge");
        assert!(
            warm.ppr.iterations <= cold.ppr.iterations,
            "warm restart must not add sweeps: warm {} vs cold {}",
            warm.ppr.iterations,
            cold.ppr.iterations
        );
        suite.report(
            "ppr_warm_restart",
            &[
                ("cold_iters", cold.ppr.iterations as f64),
                ("warm_iters", warm.ppr.iterations as f64),
                ("iters_saved", (cold.ppr.iterations - warm.ppr.iterations) as f64),
                ("warm_hits", svc.registry().stats().ppr_warm_hits as f64),
            ],
        );
        svc.shutdown();
    }

    // ---- Mixed eigen + query load on one queue --------------------------
    // Solves and queries interleave in the same submission order a real
    // client mix would produce; query latency percentiles here show the
    // head-of-line cost of sharing the queue with eigensolves.
    {
        let solves = (jobs / 4).max(2);
        let svc = EigenService::start(replicas);
        let handle = svc.register(matrix.clone()).expect("register");
        let t0 = Instant::now();
        let mut solve_tickets = Vec::with_capacity(solves);
        let mut query_tickets = Vec::with_capacity(jobs);
        for i in 0..jobs.max(solves) {
            if i < solves {
                let opts = SolveOptions { k: if i % 2 == 0 { 8 } else { 16 }, ..Default::default() };
                solve_tickets.push(svc.submit_handle(handle, opts).1);
            }
            if i < jobs {
                query_tickets.push(svc.submit_query(handle, query_vec(n, 1000 + i as u64), k, SolveOptions::default()).1);
            }
        }
        let mut lat = Vec::with_capacity(jobs);
        for t in query_tickets {
            let r = t.wait();
            assert!(r.outcome.is_ok(), "mixed query {} failed: {:?}", r.id, r.outcome.err());
            lat.push(r.queued_s + r.query_s);
        }
        for t in solve_tickets {
            let r = t.wait();
            assert!(r.outcome.is_ok(), "mixed solve {} failed: {:?}", r.id, r.outcome.err());
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = svc.stats();
        assert_eq!(stats.queries as usize, jobs);
        suite.report(
            "mixed_eigen_query",
            &[
                ("jobs_per_s", (jobs + solves) as f64 / wall),
                ("wall_s", wall),
                ("solves", solves as f64),
                ("queries", jobs as f64),
                ("query_p50_ms", percentile(&lat, 0.50) * 1e3),
                ("query_p99_ms", percentile(&lat, 0.99) * 1e3),
            ],
        );
        svc.shutdown();
    }

    suite.finish();
}

"""Pure-jnp oracles for the Pallas kernels — the build-time correctness
anchor (pytest asserts kernel == ref, ref == scipy/numpy)."""

import jax.numpy as jnp
import numpy as np


def spmv_ref(rows, cols, vals, x, *, n):
    """COO SpMV: scatter-add, no Pallas. Padding entries (0,0,0.0) add 0."""
    return jnp.zeros((n,), jnp.float32).at[rows].add(vals * x[cols])


def lanczos_step_ref(rows, cols, vals, v, v_prev, beta, *, n):
    """One Lanczos inner iteration (Algorithm 1 lines 7-9, Paige order).

    w = M v - beta * v_prev; alpha = <w, v>; w' = w - alpha v.
    Returns (w', alpha).
    """
    w = spmv_ref(rows, cols, vals, v, n=n) - beta * v_prev
    alpha = jnp.dot(w, v)
    return w - alpha * v, alpha


def jacobi_sweep_ref(sched, a, v):
    """One Brent-Luk sweep in plain numpy (sequential rotations).

    Disjoint pairs commute, so applying the K/2 rotations of a step
    sequentially equals the parallel hardware step — same invariant the
    rust model relies on.
    """
    a = np.array(a, dtype=np.float64)
    v = np.array(v, dtype=np.float64)
    k = a.shape[0]
    for step in np.asarray(sched):
        for p, q in step:
            p, q = int(p), int(q)
            theta = 0.5 * np.arctan2(2.0 * a[p, q], a[p, p] - a[q, q])
            c, s = np.cos(theta), np.sin(theta)
            g = np.eye(k)
            g[p, p] = c
            g[q, q] = c
            g[p, q] = -s
            g[q, p] = s
            a = g.T @ a @ g
            v = v @ g
    return a.astype(np.float32), v.astype(np.float32)


def tridiag_dense(alpha, beta):
    """Dense symmetric tridiagonal from (alpha, beta[: k-1])."""
    k = len(alpha)
    t = np.zeros((k, k), dtype=np.float64)
    for i in range(k):
        t[i, i] = alpha[i]
        if i + 1 < k:
            t[i, i + 1] = beta[i]
            t[i + 1, i] = beta[i]
    return t


def topk_eig_ref(alpha, beta):
    """numpy eigh on the tridiagonal, sorted by decreasing magnitude."""
    t = tridiag_dense(alpha, beta)
    w, q = np.linalg.eigh(t)
    order = np.argsort(-np.abs(w))
    return w[order], q[:, order]

"""Pallas systolic Jacobi sweep — the paper's Brent-Luk array (SS IV-C).

One *sweep* = ``K-1`` parallel steps; in step ``s`` the K/2 disjoint pairs
of the round-robin schedule rotate simultaneously:

* diagonal PEs compute ``theta = 0.5 atan2(2b, a - d)`` (Taylor datapath on
  the FPGA; here the angle comes from the same formula and the rotation is
  renormalized, matching `rust/src/jacobi/trig.rs`),
* off-diagonal PEs apply the row/column angles,
* eigenvector PEs apply the column angle.

Hardware adaptation: the K^2/4 PEs' concurrent 2x2 rotations are expressed
as K x K one-hot-selector matmuls per step (`G^T A G`), which an MXU
executes as dense matmuls — the TPU-native equivalent of the unrolled
systolic rotate. The round-robin interchange is **baked at trace time as
constant selector matrices with a static unroll** (mirroring SS IV-C2's
fixed wiring). This is deliberate: the legacy xla_extension 0.5.1 behind
the rust runtime mis-executes dynamically-indexed gathers of the schedule
inside a loop (it repeats the first pairing), while constant selectors
round-trip exactly — see EXPERIMENTS.md.

The kernel holds the full (K,K) blocks in VMEM (K <= 32 -> 8 KiB), i.e.
the whole systolic state fits one core's VMEM just as the array fits one
SLR.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def round_robin_schedule(k: int) -> np.ndarray:
    """Static Brent-Luk pairing table: ``(k-1, k/2, 2)`` int32.

    Circle method with slot 0 pinned, advanced exactly like the rust
    `RoundRobin::advance` (reverse-order in-place shifts).
    """
    assert k >= 2 and k % 2 == 0, f"round robin needs even k >= 2, got {k}"
    m = k // 2
    top = list(range(0, k, 2))
    bottom = list(range(1, k, 2))
    steps = []
    for _ in range(k - 1):
        pairs = [(min(t, b), max(t, b)) for t, b in zip(top, bottom)]
        steps.append(pairs)
        if m > 1:
            incoming_top = bottom[0]
            outgoing_top = top[m - 1]
            top[2:m] = top[1 : m - 1]
            top[1] = incoming_top
            bottom[: m - 1] = bottom[1:m]
            bottom[m - 1] = outgoing_top
    return np.asarray(steps, dtype=np.int32)


def _selectors(sched: np.ndarray):
    """Constant one-hot selector matrices per step: P[s][i] = e_{p_i}."""
    sched = np.asarray(sched)
    steps, m, _ = sched.shape
    k = 2 * m
    ps = np.zeros((steps, m, k), np.float32)
    qs = np.zeros((steps, m, k), np.float32)
    for s in range(steps):
        for i, (p, q) in enumerate(sched[s]):
            ps[s, i, int(p)] = 1.0
            qs[s, i, int(q)] = 1.0
    return ps, qs


def _make_sweep_kernel(steps: int):
    """Build the sweep kernel; selector matrices arrive as inputs (they are
    closed-over constants at the jit boundary, so they lower to HLO
    constants — never a dynamic gather)."""

    def kernel(ps_ref, qs_ref, a_ref, v_ref, a_out_ref, v_out_ref):
        a = a_ref[...]
        v = v_ref[...]
        k = a.shape[0]
        eye = jnp.eye(k, dtype=a.dtype)
        # Static unroll over the k-1 systolic steps: fixed wiring, like the
        # hardware's neighbour connections.
        for s in range(steps):
            P = ps_ref[s]  # (k/2, k), static index
            Q = qs_ref[s]
            pa = P @ a
            qa = Q @ a
            app = jnp.sum(pa * P, axis=-1)  # diag(P a P^T)
            apq = jnp.sum(pa * Q, axis=-1)
            aqq = jnp.sum(qa * Q, axis=-1)
            # Annihilating angle per diagonal PE (Fig 4a); atan2 handles
            # a == d exactly like the hardware's zero-angle convention.
            theta = 0.5 * jnp.arctan2(2.0 * apq, app - aqq)
            c = jnp.cos(theta)[:, None]
            s_ = jnp.sin(theta)[:, None]
            # G = I with 2x2 blocks [(c, -s), (s, c)] at the pair slots.
            g = (
                eye
                - P.T @ P
                - Q.T @ Q
                + P.T @ (c * P)
                + Q.T @ (c * Q)
                - P.T @ (s_ * Q)
                + Q.T @ (s_ * P)
            )
            # All K/2 rotations at once: the MXU-native systolic step.
            a = g.T @ a @ g
            v = v @ g
        a_out_ref[...] = a
        v_out_ref[...] = v

    return kernel


@functools.lru_cache(maxsize=None)
def _sweep_call(k: int):
    sched = round_robin_schedule(k)
    ps, qs = _selectors(sched)
    kernel = _make_sweep_kernel(ps.shape[0])
    call = pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((k, k), jnp.float32),
            jax.ShapeDtypeStruct((k, k), jnp.float32),
        ),
        interpret=True,
    )
    ps_c = jnp.asarray(ps)
    qs_c = jnp.asarray(qs)
    return lambda a, v: call(ps_c, qs_c, a, v)


def jacobi_sweep_pallas(sched, a, v):
    """Run one systolic sweep.

    Args:
      sched: the (concrete) table from `round_robin_schedule` — used only
        to size the kernel; the wiring is baked per k.
      a: float32[k, k] symmetric working matrix.
      v: float32[k, k] eigenvector accumulator.

    Returns:
      (a', v') after k-1 parallel steps.
    """
    k = int(np.asarray(sched).shape[1]) * 2
    return _sweep_call(k)(a, v)


def jacobi_eigh(alpha, beta, sched, *, sweeps):
    """Full phase-2 solve: tridiagonal (alpha, beta) -> (eigvals, eigvecs).

    `beta` is padded to length k (last entry ignored) so every k shares one
    artifact signature. Runs a fixed number of sweeps (AOT has no dynamic
    stopping; O(log k) + margin is chosen by the caller), then sorts by
    decreasing |eigenvalue| — the Top-K convention.
    """
    k = alpha.shape[0]
    # Mask-based construction (no scatter: the legacy xla_extension behind
    # the rust runtime mis-executes scatter-set; masks round-trip exactly).
    ii = jnp.arange(k)[:, None]
    jj = jnp.arange(k)[None, :]
    t = (
        jnp.where(ii == jj, alpha[:, None], 0.0)
        + jnp.where(jj == ii + 1, beta[:, None], 0.0)
        + jnp.where(ii == jj + 1, beta[None, :], 0.0)
    ).astype(jnp.float32)
    v = jnp.eye(k, dtype=jnp.float32)
    call = _sweep_call(int(k))

    def body(_, carry):
        a, v = carry
        return call(a, v)

    a_fin, v_fin = jax.lax.fori_loop(0, sweeps, body, (t, v))
    d = jnp.diagonal(a_fin)
    order = jnp.argsort(-jnp.abs(d))
    return d[order], v_fin[:, order]

"""Layer-1 Pallas kernels (build-time only; lowered to HLO by aot.py).

`spmv` is the memory-bound hot-spot of the Lanczos phase (SS IV-B of the
paper); `jacobi_sweep` is the compute-bound systolic step of phase 2
(SS IV-C). Both run with interpret=True: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the kernels lower to plain HLO ops while
the BlockSpec structure still documents the HBM<->VMEM schedule a real
TPU build would use (see DESIGN.md SS Hardware-Adaptation).
"""

from .spmv import spmv_pallas, PACKET_NNZ, CHUNK_NNZ
from .jacobi import jacobi_sweep_pallas, round_robin_schedule
from . import ref

__all__ = [
    "spmv_pallas",
    "jacobi_sweep_pallas",
    "round_robin_schedule",
    "ref",
    "PACKET_NNZ",
    "CHUNK_NNZ",
]

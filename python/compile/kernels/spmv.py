"""Pallas COO SpMV kernel — the paper's dataflow SpMV CU (SS IV-B).

Hardware adaptation (FPGA -> TPU, see DESIGN.md):

* The FPGA Matrix Fetch Unit streams 512-bit packets of 5 COO entries per
  clock from one HBM channel. Here the *grid* iterates over COO chunks and
  the BlockSpec stages one ``(CHUNK_NNZ,)`` slab of rows/cols/vals from HBM
  into VMEM per step — same schedule, TPU-sized granule (CHUNK_NNZ =
  1024 packets' worth keeps the three slabs + the dense vector well inside
  the ~16 MB VMEM budget; see DESIGN.md SS Perf for the footprint table).
* The Dense Vector Fetch Unit's replicated random access becomes a VMEM
  gather (``x[cols]``).
* The Aggregation Unit + Write-Back FSM become a segment-sum scatter-add
  into the output block, which every grid step aliases (the standard
  Pallas reduction-grid pattern; step 0 zero-initializes).

Padding convention (shared with the rust runtime, `runtime/spmv.rs`):
entries beyond the real nnz are ``(row=0, col=0, val=0.0)`` and scatter an
exact 0 into ``y[0]``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# COO entries per 512-bit HBM packet (3 x 32-bit words per entry).
PACKET_NNZ = 5
# Entries per grid step: 1024 packets (20 KiB of COO slab per ref in VMEM).
CHUNK_NNZ = PACKET_NNZ * 1024


def _spmv_kernel(rows_ref, cols_ref, vals_ref, x_ref, o_ref):
    """One grid step: aggregate one COO chunk into the shared output block."""
    step = pl.program_id(0)

    # Zero-initialize the accumulator on the first chunk (the Merge Unit's
    # fresh output vector for this iteration).
    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    rows = rows_ref[...]
    cols = cols_ref[...]
    vals = vals_ref[...]
    # Dense Vector Fetch Unit: gather the 5-per-cycle random accesses.
    gathered = x_ref[...][cols]
    # Aggregation Unit: multiply and segment-sum into the output stripe.
    contrib = vals * gathered
    o_ref[...] = o_ref[...] + jnp.zeros_like(o_ref).at[rows].add(contrib)


@functools.partial(jax.jit, static_argnames=("n",))
def spmv_pallas(rows, cols, vals, x, *, n):
    """``y = M x`` for a COO matrix, as a Pallas reduction-grid kernel.

    Args:
      rows, cols: int32[nnz_pad] (padding rows/cols = 0).
      vals: float32[nnz_pad] (padding vals = 0.0).
      x: float32[n].
      n: static output length.

    Returns:
      float32[n].
    """
    nnz = rows.shape[0]
    assert nnz % CHUNK_NNZ == 0, f"nnz_pad {nnz} must be a multiple of {CHUNK_NNZ}"
    grid = nnz // CHUNK_NNZ
    return pl.pallas_call(
        _spmv_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((CHUNK_NNZ,), lambda i: (i,)),  # rows slab
            pl.BlockSpec((CHUNK_NNZ,), lambda i: (i,)),  # cols slab
            pl.BlockSpec((CHUNK_NNZ,), lambda i: (i,)),  # vals slab
            pl.BlockSpec((n,), lambda i: (0,)),  # dense vector, resident
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),  # shared accumulator
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(rows, cols, vals, x)

"""Layer-2 JAX model: the compute graphs `aot.py` lowers to HLO artifacts.

Three exported functions (all calling the L1 Pallas kernels):

* ``spmv`` — one SpMV application (Algorithm 1 line 7).
* ``lanczos_step`` — the fused Lanczos inner iteration: SpMV + the Paige-
  ordered recurrence terms. The rust coordinator runs the loop (K
  iterations, reorthogonalization, breakdown handling) and calls this per
  iteration — matching the hardware split where SLR0 owns exactly this
  dataflow and the host sequences iterations.
* ``jacobi`` — the full phase-2 systolic solve on the K x K tridiagonal.

All shapes are static per artifact variant; padding conventions are shared
with `rust/src/runtime/` (see ArtifactRegistry).
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import jacobi as jk
from compile.kernels import spmv as sk


@functools.partial(jax.jit, static_argnames=("n",))
def spmv(rows, cols, vals, x, *, n):
    """y = M x through the Pallas dataflow kernel."""
    return sk.spmv_pallas(rows, cols, vals, x, n=n)


@functools.partial(jax.jit, static_argnames=("n",))
def lanczos_step(rows, cols, vals, v, v_prev, beta, *, n):
    """Fused Lanczos iteration: returns ``(w', alpha)``.

    w = M v - beta v_prev;  alpha = <w, v>;  w' = w - alpha v.
    beta is a float32 scalar (0.0 on the first iteration).
    """
    w = sk.spmv_pallas(rows, cols, vals, v, n=n) - beta * v_prev
    alpha = jnp.dot(w, v)
    return w - alpha * v, alpha


def jacobi(alpha, beta, *, k, sweeps=None):
    """Phase-2 solve for a K x K tridiagonal: ``(eigvals, eigvecs)``.

    `beta` padded to length k. Fixed sweep count: ceil(log2 k) + 4 — the
    O(log K) systolic convergence plus margin (validated against numpy in
    the pytest suite).
    """
    if sweeps is None:
        # ceil(log2 k) + margin, static. The margin is generous because the
        # AOT artifact cannot stop early: worst-case tridiagonals need a
        # few extra sweeps to push the off-diagonal below f32 resolution.
        sweeps = (k - 1).bit_length() + 7
    sched = jnp.asarray(jk.round_robin_schedule(k))
    return jk.jacobi_eigh(alpha, beta, sched, sweeps=sweeps)

"""AOT pipeline: lower the L2 model to HLO **text** under artifacts/.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that xla_extension 0.5.1
(behind the rust `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Variants produced (must mirror `rust/src/runtime/ArtifactRegistry`):

* ``spmv_n{N}_nnz{NNZ}.hlo.txt``          (N, NNZ) in SPMV_VARIANTS
* ``lanczos_step_n{N}_nnz{NNZ}.hlo.txt``  same variants
* ``jacobi_k{K}.hlo.txt``                 K in JACOBI_KS

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Keep in lockstep with rust/src/runtime/mod.rs::ArtifactRegistry.
SPMV_VARIANTS = [(1024, 20_480), (4096, 81_920), (16_384, 327_680)]
JACOBI_KS = [4, 8, 16, 32]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    print_large_constants=True is load-bearing: the default printer elides
    big dense constants as `{...}`, which xla_extension 0.5.1's text parser
    silently materializes as ZEROS (no error). Every baked constant — e.g.
    the Jacobi round-robin selector matrices — would vanish.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_spmv(n: int, nnz: int) -> str:
    i32 = jax.ShapeDtypeStruct((nnz,), jnp.int32)
    f32v = jax.ShapeDtypeStruct((nnz,), jnp.float32)
    x = jax.ShapeDtypeStruct((n,), jnp.float32)
    fn = lambda rows, cols, vals, xv: (model.spmv(rows, cols, vals, xv, n=n),)
    return to_hlo_text(jax.jit(fn).lower(i32, i32, f32v, x))


def lower_lanczos_step(n: int, nnz: int) -> str:
    i32 = jax.ShapeDtypeStruct((nnz,), jnp.int32)
    f32v = jax.ShapeDtypeStruct((nnz,), jnp.float32)
    vec = jax.ShapeDtypeStruct((n,), jnp.float32)
    scal = jax.ShapeDtypeStruct((), jnp.float32)
    fn = lambda rows, cols, vals, v, v_prev, beta: model.lanczos_step(
        rows, cols, vals, v, v_prev, beta, n=n
    )
    return to_hlo_text(jax.jit(fn).lower(i32, i32, f32v, vec, vec, scal))


def lower_jacobi(k: int) -> str:
    alpha = jax.ShapeDtypeStruct((k,), jnp.float32)
    beta = jax.ShapeDtypeStruct((k,), jnp.float32)
    fn = lambda a, b: model.jacobi(a, b, k=k)
    return to_hlo_text(jax.jit(fn).lower(alpha, beta))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact name filter (substring match)",
    )
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    wanted = args.only.split(",") if args.only else None

    jobs = []
    for n, nnz in SPMV_VARIANTS:
        jobs.append((f"spmv_n{n}_nnz{nnz}.hlo.txt", lambda n=n, nnz=nnz: lower_spmv(n, nnz)))
        jobs.append(
            (
                f"lanczos_step_n{n}_nnz{nnz}.hlo.txt",
                lambda n=n, nnz=nnz: lower_lanczos_step(n, nnz),
            )
        )
    for k in JACOBI_KS:
        jobs.append((f"jacobi_k{k}.hlo.txt", lambda k=k: lower_jacobi(k)))

    for name, build in jobs:
        if wanted and not any(w in name for w in wanted):
            continue
        path = out / name
        text = build()
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()

"""L1 correctness: the Pallas SpMV kernel against the jnp oracle and scipy.

The hypothesis sweep drives shapes, densities, index distributions, and
value ranges; every case asserts allclose against the pure-jnp reference,
and a scipy.sparse cross-check anchors the oracle itself.
"""

import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.spmv import spmv_pallas, CHUNK_NNZ


def pad_coo(rows, cols, vals, nnz_pad):
    """Apply the shared padding convention: (0, 0, 0.0) tail entries."""
    r = np.zeros(nnz_pad, np.int32)
    c = np.zeros(nnz_pad, np.int32)
    v = np.zeros(nnz_pad, np.float32)
    r[: len(rows)] = rows
    c[: len(cols)] = cols
    v[: len(vals)] = vals
    return jnp.array(r), jnp.array(c), jnp.array(v)


def run_both(rows, cols, vals, x, n, nnz_pad=CHUNK_NNZ):
    r, c, v = pad_coo(rows, cols, vals, nnz_pad)
    xj = jnp.array(x, jnp.float32)
    y_pallas = spmv_pallas(r, c, v, xj, n=n)
    y_ref = ref.spmv_ref(r, c, v, xj, n=n)
    return np.array(y_pallas), np.array(y_ref)


def test_small_hand_case():
    # [[1, 2], [0, 3]] @ [1, 1] = [3, 3]
    y, yr = run_both([0, 0, 1], [0, 1, 1], [1.0, 2.0, 3.0], [1.0, 1.0], 2)
    np.testing.assert_allclose(y, [3.0, 3.0], rtol=1e-6)
    np.testing.assert_allclose(y, yr, rtol=1e-6)


def test_matches_scipy_on_random_matrix():
    rng = np.random.default_rng(42)
    n, real = 512, 4000
    rows = rng.integers(0, n, real)
    cols = rng.integers(0, n, real)
    vals = rng.normal(size=real).astype(np.float32)
    x = rng.normal(size=n).astype(np.float32)
    y, yr = run_both(rows, cols, vals, x, n)
    m = sp.coo_matrix((vals, (rows, cols)), shape=(n, n))
    expected = m @ x
    np.testing.assert_allclose(y, expected, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(y, yr, rtol=1e-6, atol=1e-6)


def test_multi_chunk_grid():
    # nnz_pad spanning several grid steps must accumulate, not overwrite.
    rng = np.random.default_rng(7)
    n = 128
    nnz_pad = CHUNK_NNZ * 3
    real = CHUNK_NNZ * 2 + 17  # crosses chunk boundaries
    rows = rng.integers(0, n, real)
    cols = rng.integers(0, n, real)
    vals = rng.normal(size=real).astype(np.float32)
    x = rng.normal(size=n).astype(np.float32)
    y, yr = run_both(rows, cols, vals, x, n, nnz_pad=nnz_pad)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4)


def test_padding_is_neutral():
    # Same matrix with different padding amounts -> identical result.
    rows, cols, vals = [1, 2, 3], [3, 2, 1], [0.5, -1.5, 2.5]
    x = np.arange(5, dtype=np.float32)
    y1, _ = run_both(rows, cols, vals, x, 5, nnz_pad=CHUNK_NNZ)
    y2, _ = run_both(rows, cols, vals, x, 5, nnz_pad=2 * CHUNK_NNZ)
    np.testing.assert_allclose(y1, y2, rtol=1e-6)


def test_duplicate_entries_accumulate():
    y, yr = run_both([2, 2, 2], [0, 0, 1], [1.0, 2.0, 4.0], [1.0, 10.0, 0.0], 4)
    np.testing.assert_allclose(y, [0.0, 0.0, 43.0, 0.0], rtol=1e-6)
    np.testing.assert_allclose(y, yr, rtol=1e-6)


def test_zero_matrix():
    y, yr = run_both([], [], [], np.ones(8, np.float32), 8)
    np.testing.assert_allclose(y, np.zeros(8))
    np.testing.assert_allclose(yr, np.zeros(8))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=256),
    density=st.floats(min_value=0.0, max_value=0.2),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=1e-3, max_value=10.0),
)
def test_hypothesis_sweep(n, density, seed, scale):
    rng = np.random.default_rng(seed)
    real = min(int(density * n * n), CHUNK_NNZ - 1)
    rows = rng.integers(0, n, real)
    cols = rng.integers(0, n, real)
    vals = (rng.normal(size=real) * scale).astype(np.float32)
    x = rng.normal(size=n).astype(np.float32)
    y, yr = run_both(rows, cols, vals, x, n)
    np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-4 * scale)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_linearity_property(seed):
    """SpMV must be linear: M(a x + b z) = a M x + b M z."""
    rng = np.random.default_rng(seed)
    n, real = 64, 500
    rows = rng.integers(0, n, real)
    cols = rng.integers(0, n, real)
    vals = rng.normal(size=real).astype(np.float32)
    x = rng.normal(size=n).astype(np.float32)
    z = rng.normal(size=n).astype(np.float32)
    a, b = 0.7, -1.3
    y_comb, _ = run_both(rows, cols, vals, a * x + b * z, n)
    y_x, _ = run_both(rows, cols, vals, x, n)
    y_z, _ = run_both(rows, cols, vals, z, n)
    np.testing.assert_allclose(y_comb, a * y_x + b * y_z, rtol=1e-3, atol=1e-3)

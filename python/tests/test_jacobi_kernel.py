"""L1/L2 correctness: the systolic Jacobi kernel against the numpy sweep
oracle and numpy.linalg.eigh."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.jacobi import jacobi_eigh, jacobi_sweep_pallas, round_robin_schedule


def rand_tridiag(k, seed):
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(-1, 1, k).astype(np.float32)
    beta = rng.uniform(-1, 1, k).astype(np.float32)  # padded to k
    return alpha, beta


@pytest.mark.parametrize("k", [4, 6, 8, 16])
def test_schedule_meets_every_pair_once(k):
    sched = round_robin_schedule(k)
    assert sched.shape == (k - 1, k // 2, 2)
    seen = set()
    for step in sched:
        used = set()
        for p, q in step:
            assert p < q
            assert p not in used and q not in used, "pairs within a step must be disjoint"
            used.update((int(p), int(q)))
            pair = (int(p), int(q))
            assert pair not in seen, f"pair {pair} repeated"
            seen.add(pair)
    assert len(seen) == k * (k - 1) // 2


@pytest.mark.parametrize("k", [4, 8])
def test_sweep_matches_numpy_oracle(k):
    alpha, beta = rand_tridiag(k, 3)
    t = ref.tridiag_dense(alpha, beta[: k - 1]).astype(np.float32)
    v = np.eye(k, dtype=np.float32)
    sched = round_robin_schedule(k)
    a_p, v_p = jacobi_sweep_pallas(jnp.array(sched), jnp.array(t), jnp.array(v))
    a_r, v_r = ref.jacobi_sweep_ref(sched, t, v)
    np.testing.assert_allclose(np.array(a_p), a_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.array(v_p), v_r, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("k", [4, 8, 16, 32])
def test_eigenvalues_match_numpy(k):
    alpha, beta = rand_tridiag(k, 11)
    sched = round_robin_schedule(k)
    sweeps = int(np.ceil(np.log2(k))) + 4
    ev, V = jacobi_eigh(jnp.array(alpha), jnp.array(beta), jnp.array(sched), sweeps=sweeps)
    w_ref, _ = ref.topk_eig_ref(alpha, beta[: k - 1])
    np.testing.assert_allclose(np.array(ev), w_ref, rtol=1e-4, atol=1e-5)


def test_eigenvectors_are_orthonormal_and_residuals_small():
    k = 16
    alpha, beta = rand_tridiag(k, 29)
    sched = round_robin_schedule(k)
    ev, V = jacobi_eigh(jnp.array(alpha), jnp.array(beta), jnp.array(sched), sweeps=9)
    V = np.array(V, dtype=np.float64)
    ev = np.array(ev, dtype=np.float64)
    np.testing.assert_allclose(V.T @ V, np.eye(k), atol=1e-5)
    t = ref.tridiag_dense(alpha, beta[: k - 1])
    for j in range(k):
        res = np.linalg.norm(t @ V[:, j] - ev[j] * V[:, j])
        assert res < 1e-4, f"pair {j}: residual {res}"


def test_sorted_by_decreasing_magnitude():
    k = 8
    alpha, beta = rand_tridiag(k, 5)
    sched = round_robin_schedule(k)
    ev, _ = jacobi_eigh(jnp.array(alpha), jnp.array(beta), jnp.array(sched), sweeps=8)
    ev = np.abs(np.array(ev))
    assert np.all(ev[:-1] >= ev[1:] - 1e-7)


def test_diagonal_input_is_fixed_point():
    # beta = 0: already diagonal, eigenvalues = alpha (sorted by |.|).
    k = 8
    alpha = np.array([0.5, -0.9, 0.1, 0.7, -0.2, 0.05, 0.3, -0.6], np.float32)
    beta = np.zeros(k, np.float32)
    sched = round_robin_schedule(k)
    ev, V = jacobi_eigh(jnp.array(alpha), jnp.array(beta), jnp.array(sched), sweeps=4)
    np.testing.assert_allclose(np.array(ev), sorted(alpha, key=abs, reverse=True), rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(
    k=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.floats(min_value=1e-2, max_value=1.0),
)
def test_hypothesis_spectrum_sweep(k, seed, scale):
    rng = np.random.default_rng(seed)
    alpha = (rng.uniform(-1, 1, k) * scale).astype(np.float32)
    beta = (rng.uniform(-1, 1, k) * scale).astype(np.float32)
    sched = round_robin_schedule(k)
    sweeps = int(np.ceil(np.log2(k))) + 4
    ev, _ = jacobi_eigh(jnp.array(alpha), jnp.array(beta), jnp.array(sched), sweeps=sweeps)
    w_ref, _ = ref.topk_eig_ref(alpha, beta[: k - 1])
    np.testing.assert_allclose(np.array(ev), w_ref, rtol=1e-3, atol=1e-5 * scale + 1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_trace_preserved(seed):
    """Similarity transforms preserve the trace."""
    k = 8
    rng = np.random.default_rng(seed)
    alpha = rng.uniform(-1, 1, k).astype(np.float32)
    beta = rng.uniform(-1, 1, k).astype(np.float32)
    sched = round_robin_schedule(k)
    ev, _ = jacobi_eigh(jnp.array(alpha), jnp.array(beta), jnp.array(sched), sweeps=7)
    assert abs(float(np.sum(np.array(ev))) - float(np.sum(alpha))) < 1e-4

"""L2 correctness: the fused lanczos_step and the end-to-end python-side
two-phase pipeline (a miniature of what the rust coordinator runs)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.spmv import CHUNK_NNZ


def make_sym_coo(n, real, seed, nnz_pad=CHUNK_NNZ):
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, real // 2)
    c = rng.integers(0, n, real // 2)
    v = rng.normal(size=real // 2).astype(np.float32)
    rows = np.concatenate([r, c])
    cols = np.concatenate([c, r])
    vals = np.concatenate([v, v])
    # Frobenius-normalize (the design's precondition).
    vals = vals / np.linalg.norm(vals)
    rp = np.zeros(nnz_pad, np.int32)
    cp = np.zeros(nnz_pad, np.int32)
    vp = np.zeros(nnz_pad, np.float32)
    rp[: len(rows)] = rows
    cp[: len(cols)] = cols
    vp[: len(vals)] = vals
    return jnp.array(rp), jnp.array(cp), jnp.array(vp)


def test_lanczos_step_matches_ref():
    n = 128
    rows, cols, vals = make_sym_coo(n, 1000, 3)
    rng = np.random.default_rng(4)
    v = rng.normal(size=n).astype(np.float32)
    v /= np.linalg.norm(v)
    v_prev = rng.normal(size=n).astype(np.float32)
    beta = jnp.float32(0.37)
    w, alpha = model.lanczos_step(rows, cols, vals, jnp.array(v), jnp.array(v_prev), beta, n=n)
    w_r, alpha_r = ref.lanczos_step_ref(rows, cols, vals, jnp.array(v), jnp.array(v_prev), beta, n=n)
    np.testing.assert_allclose(np.array(w), np.array(w_r), rtol=1e-4, atol=1e-6)
    assert abs(float(alpha) - float(alpha_r)) < 1e-5


def test_lanczos_step_output_is_orthogonal_to_v():
    # By construction <w', v> = 0 (that is what subtracting alpha*v does).
    n = 256
    rows, cols, vals = make_sym_coo(n, 2000, 9)
    v = np.random.default_rng(1).normal(size=n).astype(np.float32)
    v /= np.linalg.norm(v)
    w, _ = model.lanczos_step(rows, cols, vals, jnp.array(v), jnp.zeros(n, jnp.float32), jnp.float32(0.0), n=n)
    assert abs(float(jnp.dot(w, jnp.array(v)))) < 1e-4


def full_pipeline(n, real, k, seed):
    """K Lanczos iterations (python mirror of the rust loop) + jacobi."""
    rows, cols, vals = make_sym_coo(n, real, seed)
    v = jnp.ones(n, jnp.float32) / jnp.sqrt(jnp.float32(n))
    v_prev = jnp.zeros(n, jnp.float32)
    beta = jnp.float32(0.0)
    alphas, betas, basis = [], [], []
    for i in range(k):
        basis.append(v)
        w, alpha = model.lanczos_step(rows, cols, vals, v, v_prev, beta, n=n)
        alphas.append(float(alpha))
        if i + 1 == k:
            break
        # Full reorthogonalization (host-side, like the rust coordinator).
        for b in basis:
            w = w - jnp.dot(w, b) * b
        b2 = float(jnp.linalg.norm(w))
        betas.append(b2)
        v_prev = v
        v = w / b2
        beta = jnp.float32(b2)
    alpha_arr = np.array(alphas, np.float32)
    beta_arr = np.zeros(k, np.float32)
    beta_arr[: k - 1] = betas
    ev, y = model.jacobi(jnp.array(alpha_arr), jnp.array(beta_arr), k=k)
    return rows, cols, vals, np.array(basis), np.array(ev), np.array(y)


def test_two_phase_pipeline_finds_dominant_eigenpair():
    n, k = 256, 8
    rows, cols, vals, basis, ev, y = full_pipeline(n, 3000, k, seed=7)
    # Lift the top eigenvector and check the residual against the operator.
    q = basis.T @ y[:, 0]
    q /= np.linalg.norm(q)
    mq = np.array(ref.spmv_ref(rows, cols, vals, jnp.array(q, jnp.float32), n=n))
    res = np.linalg.norm(mq - ev[0] * q)
    assert res < 5e-2, f"top-pair residual {res} (lambda={ev[0]})"


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_pipeline_eigenvalues_within_gershgorin(seed):
    n, k = 128, 6
    *_, ev, _ = full_pipeline(n, 1500, k, seed=seed)
    # All Ritz values lie within the field of values of M: |lambda| <= ||M||_F = 1.
    assert np.all(np.abs(ev) <= 1.0 + 1e-5)


def test_pipeline_matches_scipy_arpack():
    """Cross-check against the paper's actual baseline library: scipy's
    eigsh wraps ARPACK (IRAM). The dominant eigenvalues of the two-phase
    pipeline must agree with ARPACK's converged values."""
    import scipy.sparse as sp
    import scipy.sparse.linalg as spla

    n, k = 256, 10
    rows, cols, vals = make_sym_coo(n, 3000, seed=21)
    r, c, v = np.array(rows), np.array(cols), np.array(vals)
    mask = (r != 0) | (c != 0) | (v != 0)  # drop padding except a genuine (0,0) would be kept by v!=0
    m = sp.coo_matrix((v[mask], (r[mask], c[mask])), shape=(n, n)).tocsr()
    want = spla.eigsh(m, k=3, which="LM", return_eigenvectors=False, tol=1e-10)
    want = want[np.argsort(-np.abs(want))]

    *_, ev, _ = full_pipeline(n, 3000, k, seed=21)
    # Top ARPACK eigenvalue must appear as the pipeline's top Ritz value.
    assert abs(ev[0] - want[0]) < 1e-2 * abs(want[0]), f"pipeline {ev[0]} vs ARPACK {want[0]}"

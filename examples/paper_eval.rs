//! End-to-end paper evaluation driver (recorded in EXPERIMENTS.md).
//!
//! Regenerates, on the synthetic Table II suite, the shape of every result
//! in the paper's §V:
//!
//! * Table II — the dataset suite (published sizes + generated twins).
//! * Fig 9    — speedup of the FPGA design (timing model) over the
//!              measured multi-threaded restarted-Lanczos CPU baseline,
//!              per graph, for K in {8, 16, 24}; geomean excluding HT.
//! * Fig 10a  — time to process one non-zero vs graph size (flat for the
//!              FPGA model, erratic for the CPU).
//! * Fig 10b  — systolic-vs-cyclic Jacobi speedup for growing K.
//! * Fig 11   — orthogonality + reconstruction error vs K and reorth
//!              policy (measured, with the fixed-point datapath).
//! * Table I  — resource model of the shipped design.
//! * §V-B     — power-efficiency ratios.
//! * AOT path — one solve through the PJRT artifacts proves L1/L2/L3
//!              compose.
//!
//! ```bash
//! cargo run --release --example paper_eval -- [scale]   # default 256
//! ```

use std::time::Instant;
use topk_eigen::coordinator::{verify, Engine, SolveOptions, Solver};
use topk_eigen::fixed::Precision;
use topk_eigen::fpga::{self, FpgaTimingModel, PowerModel, SlrBudget};
use topk_eigen::graphs;
use topk_eigen::iram::{iram, IramOptions};
use topk_eigen::jacobi::{self, TrigMode};
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::linalg::Tridiagonal;
use topk_eigen::sparse::{normalize_frobenius, partition_rows_balanced, PartitionPolicy};
use topk_eigen::util::rng::Pcg64;
use topk_eigen::util::timer::geomean;

fn main() -> anyhow::Result<()> {
    topk_eigen::util::logging::init();
    let scale: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    println!("== paper_eval: Table II synthetic suite at 1/{scale} scale ==\n");

    // ---------------- Table II ----------------
    println!("--- Table II: evaluation suite ---");
    println!(
        "{:<6} {:<16} {:>11} {:>12} | {:>10} {:>12}",
        "ID", "name", "rows(pub)", "nnz(pub)", "rows(gen)", "nnz(gen)"
    );
    let mut suite = Vec::new();
    for e in graphs::catalog() {
        let mut g = e.generate(scale);
        normalize_frobenius(&mut g);
        println!(
            "{:<6} {:<16} {:>11} {:>12} | {:>10} {:>12}",
            e.id,
            e.name,
            e.rows,
            e.nnz,
            g.nrows,
            g.nnz()
        );
        suite.push((e, g));
    }

    // ---------------- Fig 9 + Fig 10a ----------------
    let model = FpgaTimingModel::default();
    let power = PowerModel::default();
    let ks = [8usize, 16, 24];
    println!("\n--- Fig 9: speedup vs CPU baseline (FPGA timing model / measured thick-restart Lanczos) ---");
    println!(
        "{:<6} {:>4} {:>12} {:>12} {:>9} {:>12} {:>14}",
        "ID", "K", "cpu(s)", "fpga(s)", "speedup", "perf/W", "cpu ns/nnz"
    );
    let mut fig9: Vec<(String, usize, f64)> = Vec::new();
    let mut fig10a: Vec<(String, usize, f64, f64)> = Vec::new();
    // Multi-threaded CPU baseline, like the paper's 80-thread ARPACK: the
    // SpMV inside the restarted solver runs on all host cores.
    let pool = std::sync::Arc::new(topk_eigen::util::pool::ThreadPool::with_default_parallelism());
    for (e, g) in &suite {
        let csr = std::sync::Arc::new(g.to_csr());
        for &k in &ks {
            // CPU baseline: measured restarted Lanczos (ARPACK surrogate).
            let op = topk_eigen::lanczos::ShardedSpmv::new(
                std::sync::Arc::clone(&csr),
                pool.size(),
                PartitionPolicy::BalancedNnz,
                std::sync::Arc::clone(&pool),
            );
            let t0 = Instant::now();
            let base = iram(&op, &IramOptions { k, tol: 1e-6, ..Default::default() });
            let cpu_s = t0.elapsed().as_secs_f64();

            // FPGA: timing model with the measured systolic step count.
            let shards = partition_rows_balanced(&csr, 5, PartitionPolicy::EqualRows);
            let lz = topk_eigen::lanczos::lanczos(
                csr.as_ref(),
                &topk_eigen::lanczos::LanczosOptions { k, reorth: ReorthPolicy::EveryN(2), ..Default::default() },
            );
            let (_, _, stats) = jacobi::systolic_jacobi(&lz.tridiag.to_dense(), TrigMode::Taylor3, 1e-9, 100);
            let t = model.solve_time(csr.nrows, &shards, k, ReorthPolicy::EveryN(2), stats.steps);
            let speedup = cpu_s / t.total_s();
            let p = power.compare(t.total_s(), cpu_s);
            if k == 16 {
                fig10a.push((
                    e.id.to_string(),
                    csr.nnz(),
                    cpu_s / csr.nnz() as f64 * 1e9,
                    t.total_s() / csr.nnz() as f64 * 1e9,
                ));
            }
            fig9.push((e.id.to_string(), k, speedup));
            println!(
                "{:<6} {:>4} {:>12.4} {:>12.6} {:>8.1}x {:>11.0}x {:>14.1}",
                e.id,
                k,
                cpu_s,
                t.total_s(),
                speedup,
                p.perf_per_watt_gain,
                cpu_s / csr.nnz() as f64 * 1e9
            );
            let _ = base;
        }
    }
    for &k in &ks {
        let sp: Vec<f64> =
            fig9.iter().filter(|(id, kk, _)| *kk == k && id != "HT").map(|(_, _, s)| *s).collect();
        println!("geomean speedup (K={k}, excl. HT as in the paper): {:.2}x", geomean(&sp));
    }

    println!("\n--- Fig 10a: ns per non-zero vs graph size (CPU erratic, FPGA flat) ---");
    println!("{:<6} {:>12} {:>14} {:>14}", "ID", "nnz", "cpu ns/nnz", "fpga ns/nnz");
    for (id, nnz, cpu, fpga) in &fig10a {
        println!("{id:<6} {nnz:>12} {cpu:>14.2} {fpga:>14.3}");
    }

    // ---------------- Fig 10b ----------------
    println!("\n--- Fig 10b: Jacobi systolic (model) vs cyclic CPU (measured) ---");
    println!("{:>4} {:>12} {:>12} {:>9}", "K", "cpu(us)", "fpga(us)", "speedup");
    let mut rng = Pcg64::new(99);
    for k in [4usize, 8, 16, 32] {
        let t = Tridiagonal::new(
            (0..k).map(|_| rng.f64_range(-1.0, 1.0)).collect(),
            (0..k - 1).map(|_| rng.f64_range(-1.0, 1.0)).collect(),
        );
        let dense = t.to_dense();
        let t0 = Instant::now();
        let iters = 200;
        for _ in 0..iters {
            let _ = jacobi::cyclic_jacobi(&dense, TrigMode::Exact, 1e-10, 100);
        }
        let cpu_us = t0.elapsed().as_secs_f64() / iters as f64 * 1e6;
        let (_, _, stats) = jacobi::systolic_jacobi(&dense, TrigMode::Taylor3, 1e-9, 100);
        let fpga_us = model.jacobi_cycles(k, stats.steps) as f64 / fpga::U280::CLOCK_HZ * 1e6;
        println!("{k:>4} {cpu_us:>12.2} {fpga_us:>12.3} {:>8.1}x", cpu_us / fpga_us);
    }

    // ---------------- Fig 11 ----------------
    println!("\n--- Fig 11: accuracy vs K (fixed-point Lanczos datapath, measured) ---");
    println!("{:>4} {:<10} {:>14} {:>16}", "K", "reorth", "angle(deg)", "resid(norm'd)");
    let acc_suite: Vec<&(graphs::CatalogEntry, topk_eigen::sparse::CooMatrix)> =
        suite.iter().filter(|(e, _)| ["WB-GO", "IT", "PA"].contains(&e.id)).collect();
    for &k in &[8usize, 12, 16, 20, 24] {
        for policy in [ReorthPolicy::EveryN(2), ReorthPolicy::None] {
            let (mut angle, mut resid) = (0.0, 0.0);
            for (_, g) in &acc_suite {
                let mut solver = Solver::new(SolveOptions {
                    k,
                    reorth: policy,
                    precision: Precision::FixedQ1_31,
                    ..Default::default()
                });
                let sol = solver.solve(g)?;
                let r = verify::verify(g, &sol);
                angle += r.mean_angle_deg;
                resid += r.mean_residual;
            }
            let nsuite = acc_suite.len() as f64;
            println!("{k:>4} {:<10} {:>14.3} {:>16.3e}", policy.name(), angle / nsuite, resid / nsuite);
        }
    }

    // ---------------- Table I ----------------
    println!("\n--- Table I: resource model (percent of one SLR) ---");
    println!("{:<18} {:>6} {:>6} {:>6} {:>6} {:>6}", "core", "LUT%", "FF%", "BRAM%", "URAM%", "DSP%");
    let rows = [
        ("Lanczos (5 CU)", fpga::lanczos_core_resources(5)),
        ("Jacobi K=32", fpga::jacobi_core_resources(32)),
        ("Jacobi 2xK=16", fpga::jacobi_core_resources(16).plus(fpga::jacobi_core_resources(16))),
    ];
    for (name, u) in rows {
        let (lut, ff, bram, uram, dsp) = SlrBudget::utilization_pct(u);
        println!("{name:<18} {lut:>6.0} {ff:>6.0} {bram:>6.0} {uram:>6.0} {dsp:>6.0}");
    }

    // ---------------- AOT / PJRT composition check ----------------
    println!("\n--- AOT path: solve through PJRT artifacts (L1 Pallas -> L2 JAX -> HLO -> rust) ---");
    let (e, g) = &suite[1]; // web-Google twin
    if g.nrows <= 16_384 {
        let mut solver = Solver::new(SolveOptions { k: 8, engine: Engine::Pjrt, ..Default::default() });
        let t0 = Instant::now();
        let sol = solver.solve(g)?;
        let r = verify::verify(g, &sol);
        println!(
            "{}: engine={} lambda0={:+.5} angle={:.2}deg resid={:.2e} ({:.2}s)",
            e.id,
            sol.metrics.engine_used,
            sol.eigenvalues[0],
            r.mean_angle_deg,
            r.mean_residual,
            t0.elapsed().as_secs_f64()
        );
        anyhow::ensure!(sol.metrics.engine_used == "pjrt", "PJRT path did not engage");
    } else {
        println!("skipped (scale too large for compiled artifact shapes; rerun with scale >= 256)");
    }

    println!("\npaper_eval OK");
    Ok(())
}

//! Quickstart: build a graph, solve the Top-K eigenproblem, verify.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use topk_eigen::coordinator::service::EigenService;
use topk_eigen::coordinator::{verify, SolveOptions, Solver};
use topk_eigen::graphs;
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::util::timer::fmt_duration;

fn main() -> anyhow::Result<()> {
    topk_eigen::util::logging::init();

    // 1. A power-law graph, like the web/social networks in the paper's
    //    Table II (R-MAT, 16k vertices, ~8 edges per vertex).
    let n = 1 << 14;
    let adj = graphs::rmat(n, 16 * n, 0.57, 0.19, 0.19, /*seed=*/ 42);
    println!("graph: {} vertices, {} non-zeros", adj.nrows, adj.nnz());

    // 2. Solve for the Top-8 eigenpairs with the paper's configuration:
    //    5 SpMV compute units, reorthogonalization every 2 iterations,
    //    systolic-array Jacobi for the K x K phase.
    let opts = SolveOptions { k: 8, reorth: ReorthPolicy::EveryN(2), ..Default::default() };
    let mut solver = Solver::new(opts);
    let sol = solver.solve(&adj)?;

    println!("\nTop-{} eigenvalues:", sol.k());
    for (i, (lambda, _v)) in sol.pairs().enumerate() {
        println!("  lambda[{i}] = {lambda:+.6}");
    }

    // 3. Phase breakdown (the paper's §V-A: SpMV dominates).
    let m = &sol.metrics;
    println!(
        "\nphases: prepare={} lanczos={} jacobi={} lift={}",
        fmt_duration(m.prepare_s),
        fmt_duration(m.lanczos_s),
        fmt_duration(m.jacobi_s),
        fmt_duration(m.lift_s)
    );
    println!("SpMV applications: {} (exactly K — the single-pass property)", m.spmv_count);
    println!("systolic sweeps:   {} (O(log K) convergence)", m.systolic.sweeps);

    // 4. Fig 11 accuracy metrics.
    let r = verify::verify(&adj, &sol);
    println!(
        "\naccuracy: mean pairwise angle = {:.3} deg (ideal 90), mean ||Mv - lv|| = {:.3e}",
        r.mean_angle_deg, r.mean_residual
    );
    anyhow::ensure!(r.mean_angle_deg > 89.0, "orthogonality regression");

    // 5. Block Lanczos: the same Top-8 solve at block width 4 advances
    //    four Krylov columns per matrix stream, so the HBM value-array
    //    traffic per iteration is shared by the whole panel. The adaptive
    //    budget lets both paths run to Ritz stabilization; the block path
    //    gets there in a fraction of the matrix passes.
    let bopts = SolveOptions {
        k: 8,
        block_size: 4,
        reorth: ReorthPolicy::EveryN(2),
        adaptive_tol: Some(1e-6),
        ..Default::default()
    };
    let bsol = Solver::new(bopts).solve(&adj)?;
    let bm = &bsol.metrics;
    println!(
        "\nblock b=4: {} matrix passes x {} columns = {} SpMVs ({} passes single-vector)",
        bm.matrix_passes, bm.block_size, bm.spmv_count, m.matrix_passes
    );
    println!(
        "matrix bytes streamed: {:.1} MiB vs {:.1} MiB single-vector",
        bm.bytes_streamed as f64 / (1 << 20) as f64,
        m.bytes_streamed as f64 / (1 << 20) as f64
    );
    let rel = (bsol.eigenvalues[0] - sol.eigenvalues[0]).abs() / sol.eigenvalues[0].abs();
    anyhow::ensure!(rel < 5e-3, "block leading eigenvalue diverged: rel {rel:.2e}");

    // 6. Batched streaming queries on the serving path: register the graph
    //    once, then answer a batch of Top-K SpMV queries with ONE matrix
    //    sweep for the whole batch. Every member's answer is bitwise equal
    //    to submitting it alone — batching changes bytes moved, not bits.
    let svc = EigenService::start(2);
    let handle = svc.register(adj)?;
    let queries: Vec<Vec<f32>> = (0..4)
        .map(|q| (0..n).map(|i| ((i * 31 + q * 17 + 3) % 101) as f32 / 101.0 - 0.5).collect())
        .collect();
    let tickets = svc.submit_query_batch(handle, queries, 5, SolveOptions::default());
    println!("\nbatched Top-5 queries (one sweep answers all {}):", tickets.len());
    for (id, t) in tickets {
        let answer = t.wait().outcome.map_err(anyhow::Error::msg)?;
        let top: Vec<String> =
            answer.entries.iter().map(|e| format!("{}:{:+.4}", e.index, e.score)).collect();
        println!("  query {id}: [{}]", top.join(", "));
    }
    let stats = svc.stats();
    println!("query batches: {} ({} queries)", stats.query_batches, stats.batched_queries);
    svc.shutdown();

    println!("\nquickstart OK");
    Ok(())
}

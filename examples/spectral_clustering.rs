//! Spectral clustering — the application the paper motivates (§I).
//!
//! Builds a planted-partition graph with known communities, embeds the
//! vertices with the Top-K eigenvectors of the normalized adjacency
//! (Ng-Jordan-Weiss), clusters the embedding with k-means, and scores the
//! recovered communities against the ground truth (purity + NMI).
//!
//! ```bash
//! cargo run --release --example spectral_clustering
//! ```

use topk_eigen::coordinator::{SolveOptions, Solver};
use topk_eigen::graphs::{self, LaplacianKind};
use topk_eigen::lanczos::ReorthPolicy;
use topk_eigen::util::rng::Pcg64;

const COMMUNITIES: usize = 4;
const VERTICES: usize = 2_000;

fn main() -> anyhow::Result<()> {
    topk_eigen::util::logging::init();

    // 1. Planted-partition graph: 4 communities, strong assortativity.
    let (adj, truth) = graphs::planted_partition(VERTICES, COMMUNITIES, 0.03, 0.0005, 7);
    println!("graph: {} vertices, {} edges, {} planted communities", adj.nrows, adj.nnz() / 2, COMMUNITIES);

    // 2. Top-K eigenvectors of W = D^-1/2 A D^-1/2. A random start vector
    //    matters here: the uniform start is orthogonal to the community-
    //    difference eigenvectors on equal-size communities.
    let w = graphs::adjacency_to_laplacian(&adj, LaplacianKind::NormalizedAdjacency);
    // k well above the community count: single-pass Lanczos needs the
    // extra Krylov dimensions to converge the top eigenvectors when the
    // spectral gap ratio is ~0.8 (6 steps would leave ~30% residual).
    let mut solver = Solver::new(SolveOptions {
        k: 24,
        reorth: ReorthPolicy::Every,
        ..Default::default()
    });
    let mut rng = Pcg64::new(13);
    let sol = solve_with_random_start(&mut solver, &w, &mut rng)?;
    println!("top eigenvalues: {:?}", &sol.eigenvalues[..COMMUNITIES.min(sol.k())]);

    // 3. Embed: rows of the n x k eigenvector matrix, row-normalized (NJW).
    let k = COMMUNITIES;
    let mut embed = vec![[0.0f64; COMMUNITIES]; VERTICES];
    for (j, (_lambda, vec)) in sol.pairs().take(k).enumerate() {
        for (i, &x) in vec.iter().enumerate() {
            embed[i][j] = x as f64;
        }
    }
    for row in &mut embed {
        let norm: f64 = row.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 0.0 {
            row.iter_mut().for_each(|x| *x /= norm);
        }
    }

    // 4. k-means on the embedding.
    let labels = kmeans(&embed, COMMUNITIES, 50, &mut rng);

    // 5. Score.
    let purity = purity(&labels, &truth, COMMUNITIES);
    let nmi = nmi(&labels, &truth, COMMUNITIES);
    println!("purity = {purity:.3}, NMI = {nmi:.3}");
    anyhow::ensure!(purity > 0.85, "clustering should recover planted structure (purity {purity})");
    println!("spectral_clustering OK");
    Ok(())
}

fn solve_with_random_start(
    solver: &mut Solver,
    w: &topk_eigen::sparse::CooMatrix,
    rng: &mut Pcg64,
) -> anyhow::Result<topk_eigen::coordinator::Solution> {
    // The Solver uses the paper's uniform start; emulate a random start by
    // perturbing the operator call path: run Lanczos directly.
    use topk_eigen::jacobi::{jacobi_eigen, JacobiMode};
    use topk_eigen::lanczos::{lanczos, lift_eigenvector, LanczosOptions};
    let mut m = w.clone();
    m.canonicalize();
    let fro = topk_eigen::sparse::normalize_frobenius(&mut m);
    let csr = m.to_csr();
    let opts = solver.options();
    let v1: Vec<f32> = (0..csr.nrows).map(|_| rng.normal() as f32).collect();
    let res = lanczos(
        &csr,
        &LanczosOptions {
            k: opts.k,
            reorth: opts.reorth,
            precision: opts.precision,
            v1: Some(v1),
            ..Default::default()
        },
    );
    let eig = jacobi_eigen(&res.tridiag, JacobiMode::Systolic, 1e-10);
    let k_eff = res.k();
    let mut eigenvalues = Vec::with_capacity(k_eff);
    let mut eigenvectors = Vec::with_capacity(k_eff);
    for j in 0..k_eff {
        eigenvalues.push(eig.eigenvalues[j] * fro);
        eigenvectors.push(lift_eigenvector(&res.basis, &eig.eigenvectors.col(j)));
    }
    Ok(topk_eigen::coordinator::Solution {
        eigenvalues,
        eigenvectors,
        frobenius_norm: fro,
        metrics: Default::default(),
    })
}

/// Plain Lloyd k-means with k-means++-style seeding.
fn kmeans(points: &[[f64; COMMUNITIES]], k: usize, iters: usize, rng: &mut Pcg64) -> Vec<usize> {
    let n = points.len();
    let mut centers: Vec<[f64; COMMUNITIES]> = Vec::with_capacity(k);
    centers.push(points[rng.range(0, n)]);
    while centers.len() < k {
        // Pick the point farthest from existing centers (greedy ++).
        let far = (0..n)
            .max_by(|&a, &b| {
                let da = nearest_dist(&centers, &points[a]);
                let db = nearest_dist(&centers, &points[b]);
                da.partial_cmp(&db).unwrap()
            })
            .unwrap();
        centers.push(points[far]);
    }
    let mut labels = vec![0usize; n];
    for _ in 0..iters {
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let best = (0..k)
                .min_by(|&a, &b| dist2(&centers[a], p).partial_cmp(&dist2(&centers[b], p)).unwrap())
                .unwrap();
            if labels[i] != best {
                labels[i] = best;
                changed = true;
            }
        }
        let mut sums = vec![[0.0f64; COMMUNITIES]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            counts[labels[i]] += 1;
            for d in 0..COMMUNITIES {
                sums[labels[i]][d] += p[d];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for d in 0..COMMUNITIES {
                    centers[c][d] = sums[c][d] / counts[c] as f64;
                }
            }
        }
        if !changed {
            break;
        }
    }
    labels
}

fn dist2(a: &[f64; COMMUNITIES], b: &[f64; COMMUNITIES]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

fn nearest_dist(centers: &[[f64; COMMUNITIES]], p: &[f64; COMMUNITIES]) -> f64 {
    centers.iter().map(|c| dist2(c, p)).fold(f64::INFINITY, f64::min)
}

/// Fraction of vertices whose cluster's majority truth-label matches.
fn purity(labels: &[usize], truth: &[usize], k: usize) -> f64 {
    let mut correct = 0usize;
    for c in 0..k {
        let mut counts = vec![0usize; k];
        for (l, t) in labels.iter().zip(truth) {
            if *l == c {
                counts[*t] += 1;
            }
        }
        correct += counts.iter().max().copied().unwrap_or(0);
    }
    correct as f64 / labels.len() as f64
}

/// Normalized mutual information between two labelings.
fn nmi(labels: &[usize], truth: &[usize], k: usize) -> f64 {
    let n = labels.len() as f64;
    let mut joint = vec![vec![0.0f64; k]; k];
    let mut pl = vec![0.0f64; k];
    let mut pt = vec![0.0f64; k];
    for (&l, &t) in labels.iter().zip(truth) {
        joint[l][t] += 1.0;
        pl[l] += 1.0;
        pt[t] += 1.0;
    }
    let mut mi = 0.0;
    for l in 0..k {
        for t in 0..k {
            if joint[l][t] > 0.0 {
                mi += joint[l][t] / n * ((n * joint[l][t]) / (pl[l] * pt[t])).ln();
            }
        }
    }
    let h = |p: &[f64]| -> f64 {
        p.iter().filter(|&&x| x > 0.0).map(|&x| -(x / n) * (x / n).ln()).sum()
    };
    let (hl, ht) = (h(&pl), h(&pt));
    if hl == 0.0 || ht == 0.0 {
        return 1.0;
    }
    mi / (hl * ht).sqrt()
}

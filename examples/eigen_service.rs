//! Data-center serving demo: the multi-tenant eigensolver service (§I —
//! "applications on top of Top-K eigenproblem are mostly encountered in
//! data centers").
//!
//! Starts N solver replicas, submits a batch of mixed-size eigenproblem
//! jobs, and reports throughput and queue/solve latency percentiles. A
//! second phase demonstrates `submit_batch`: several K values over one
//! matrix, sharing a single prepare (CSR + sharded engine) on one worker.
//!
//! ```bash
//! cargo run --release --example eigen_service -- [jobs] [replicas]
//! ```

use std::time::Instant;
use topk_eigen::coordinator::service::EigenService;
use topk_eigen::coordinator::SolveOptions;
use topk_eigen::graphs;
use topk_eigen::util::timer::{fmt_duration, Stats};

fn main() -> anyhow::Result<()> {
    topk_eigen::util::logging::init();
    let jobs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let replicas: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    println!("eigen_service: {jobs} jobs across {replicas} solver replicas");

    let svc = EigenService::start(replicas);
    let t0 = Instant::now();
    let mut tickets = Vec::new();
    for i in 0..jobs {
        // Mixed workload: alternating topology classes and sizes, like a
        // shared analytics cluster would see.
        let matrix = match i % 3 {
            0 => graphs::rmat(1 << (9 + i % 3), 8 << (9 + i % 3), 0.57, 0.19, 0.19, i as u64),
            1 => graphs::mesh2d(24 + i, 24 + i, 0.9, 0.01, i as u64),
            _ => graphs::scale_free_ba(800 + 50 * (i % 5), 4, i as u64),
        };
        let k = 4 + (i % 3) * 4;
        let (_id, ticket) = svc.submit(matrix, SolveOptions { k, ..Default::default() });
        tickets.push(ticket);
    }

    let mut queue = Stats::new();
    let mut ok = 0usize;
    for t in tickets {
        let r = t.wait();
        queue.push(r.queued_s);
        match r.outcome {
            Ok(sol) => {
                ok += 1;
                log::debug!("job {} -> lambda0 {:+.4}", r.id, sol.eigenvalues[0]);
            }
            Err(e) => println!("job {} failed: {e}", r.id),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "completed {ok}/{jobs} jobs in {} -> {:.1} jobs/s",
        fmt_duration(wall),
        jobs as f64 / wall
    );
    println!(
        "queue wait: p50={} p90={} max={}",
        fmt_duration(queue.median()),
        fmt_duration(queue.percentile(90.0)),
        fmt_duration(queue.max())
    );
    anyhow::ensure!(ok == jobs, "all jobs must succeed");

    // Batched phase: one matrix, several K values — the service runs the
    // prepare phase once and shares the sharded SpMV engine across solves.
    let ks = [4usize, 8, 12, 16];
    let matrix = graphs::rmat(1 << 12, 8 << 12, 0.57, 0.19, 0.19, 1234);
    let t1 = Instant::now();
    let batch = svc.submit_batch(matrix, SolveOptions::default(), &ks);
    let mut batch_ok = 0usize;
    for (id, ticket) in batch {
        let r = ticket.wait();
        match r.outcome {
            Ok(sol) => {
                batch_ok += 1;
                println!(
                    "batch job {id}: k={} lambda0={:+.4} solve={}",
                    sol.k(),
                    sol.eigenvalues[0],
                    fmt_duration(r.solve_s)
                );
            }
            Err(e) => println!("batch job {id} failed: {e}"),
        }
    }
    println!("batch of {} Ks over one matrix in {}", ks.len(), fmt_duration(t1.elapsed().as_secs_f64()));
    anyhow::ensure!(batch_ok == ks.len(), "all batch members must succeed");

    // Matrix-resident phase: register the matrix once and fan mixed-K
    // handle jobs across every replica. The queue carries handles (a few
    // words), all workers solve on the shared prepared engine, and the
    // registry telemetry shows exactly one prepare.
    let handle = svc.register(graphs::rmat(1 << 12, 8 << 12, 0.57, 0.19, 0.19, 99))?;
    let t2 = Instant::now();
    let resident_ks = [4usize, 8, 12, 16, 8, 4, 16, 12];
    let tickets = svc.submit_handle_batch(handle, SolveOptions::default(), &resident_ks);
    let mut resident_ok = 0usize;
    for (id, ticket) in tickets {
        let r = ticket.wait();
        match r.outcome {
            Ok(sol) => {
                resident_ok += 1;
                log::debug!("handle job {id}: k={} lambda0={:+.4}", sol.k(), sol.eigenvalues[0]);
            }
            Err(e) => println!("handle job {id} failed: {e}"),
        }
    }
    let rstats = svc.registry().stats();
    println!(
        "matrix-resident: {} jobs over one handle in {} (prepares={}, engine hits={}, resident={:.1}MiB)",
        resident_ks.len(),
        fmt_duration(t2.elapsed().as_secs_f64()),
        rstats.prepares,
        rstats.engine_hits,
        rstats.resident_bytes as f64 / (1 << 20) as f64,
    );
    anyhow::ensure!(resident_ok == resident_ks.len(), "all handle jobs must succeed");
    anyhow::ensure!(rstats.prepares == 1, "one handle, one engine key -> one prepare");

    // Update phase: the registered graph evolves in place. Interleave
    // small symmetric deltas with handle solves on every replica — the
    // generation fence guarantees no solve ever sees a torn matrix, and
    // stale engines refresh incrementally (dirty shards only).
    let mut mirror = graphs::rmat(1 << 12, 8 << 12, 0.57, 0.19, 0.19, 99);
    mirror.canonicalize();
    let t3 = Instant::now();
    let update_rounds = 4usize;
    let mut update_ok = 0usize;
    let mut evolving_ok = 0usize;
    for round in 0..update_rounds {
        let mut delta = topk_eigen::sparse::CooDelta::new(mirror.nrows, mirror.ncols);
        let mut picked = 0usize;
        for i in 0..mirror.nnz() {
            let (r, c) = (mirror.rows[i] as usize, mirror.cols[i] as usize);
            if r <= c {
                picked += 1;
                if (picked + round) % 200 == 0 {
                    delta.upsert_sym(r, c, mirror.vals[i] * 1.05 + 1e-4);
                }
            }
        }
        let mut local = delta.clone();
        local.canonicalize();
        mirror.apply_delta(&local);
        let (_, ut) = svc.submit_update(handle, delta);
        let solves: Vec<_> = [4usize, 8, 12]
            .iter()
            .map(|&k| svc.submit_handle(handle, SolveOptions { k, ..Default::default() }).1)
            .collect();
        let ur = ut.wait();
        match ur.outcome {
            Ok(rep) => {
                update_ok += 1;
                println!(
                    "update round {round}: gen={} dirty-rows={} rel-delta={:.2e} warm-{}",
                    rep.generation,
                    rep.dirty_rows,
                    rep.rel_delta,
                    if rep.warm_kept { "kept" } else { "dropped" }
                );
            }
            Err(e) => println!("update round {round} FAILED: {e}"),
        }
        for t in solves {
            if t.wait().outcome.is_ok() {
                evolving_ok += 1;
            }
        }
    }
    let rstats = svc.registry().stats();
    println!(
        "evolving phase: {update_rounds} updates + {evolving_ok} solves in {} \
         (generations={}, incremental-rebuilds={}, full-rebuilds={}, shards-reused={})",
        fmt_duration(t3.elapsed().as_secs_f64()),
        svc.registry().generation(handle).unwrap_or(0),
        rstats.incremental_rebuilds,
        rstats.full_rebuilds,
        rstats.shards_reused,
    );
    anyhow::ensure!(update_ok == update_rounds, "all updates must succeed");
    anyhow::ensure!(evolving_ok == 3 * update_rounds, "all evolving-phase solves must succeed");

    let stats = svc.stats();
    println!(
        "service stats: submitted={} completed={} failed={} batches={} reconfigs={} total_solve={} max_queue_wait={}",
        stats.submitted,
        stats.completed,
        stats.failed,
        stats.batches,
        stats.reconfigs,
        fmt_duration(stats.total_solve_s),
        fmt_duration(stats.max_queued_s)
    );
    println!("eigen_service OK");
    Ok(())
}
